"""Post-compile HLO analysis: loop-aware FLOPs / HBM traffic / collective
bytes + roofline terms.

Why not compiled.cost_analysis(): XLA counts while-loop BODIES ONCE — a
48-layer scanned stack reports ~1/48th of its FLOPs (verified: doubling
grad-accumulation microbatch count 'halved' the reported flops). This
parser instead:

  1. splits the optimized HLO into computations and instructions,
  2. reads each while's backend_config known_trip_count and propagates
     multipliers through the call graph (nested loops multiply; fusion-
     called computations are excluded — their cost is the call site's
     operands/outputs),
  3. FLOPs: 2 * prod(out_shape) * prod(contracted lhs dims) per dot,
     weighted by the enclosing multiplier,
  4. HBM traffic: sum of (operand bytes + output bytes) of top-level
     instructions (fusions = inputs+outputs, internals free; parameter /
     gte / tuple / bitcast / constant / control ops free),
  5. collective bytes: operand sizes of all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute (-start forms only),
     weighted.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}: ]+?)\s+([\w\-]+)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_FREE_OPS = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "while", "conditional", "call", "after-all", "partition-id", "replica-id",
    "opt-barrier", "iota",
    # 'copy' is dominated by while-carry copies the CPU pipeline inserts
    # conservatively; TPU buffer assignment aliases loop carries, so
    # counting them would inflate HBM traffic ~N_layers x.
    "copy",
}


def type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    tail: str  # operands + attrs
    is_root: bool = False


@dataclass
class HloAnalysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_bytes_by_op: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    collective_count_by_op: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    raw_flops: float = 0.0  # unweighted (loop bodies once)
    raw_collective_bytes: float = 0.0

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_bytes_by_op": dict(self.collective_bytes_by_op),
            "collective_count_by_op": dict(self.collective_count_by_op),
            "raw_flops": self.raw_flops,
            "raw_collective_bytes": self.raw_collective_bytes,
        }


def parse_computations(hlo_text: str) -> Tuple[Dict[str, List[Instr]], Optional[str]]:
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = mc.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            comps[cur].append(
                Instr(
                    mi.group(1), mi.group(2), mi.group(3), mi.group(4),
                    is_root="ROOT" in line[: mi.start(1)],
                )
            )
    return comps, entry


def analyze_hlo(hlo_text: str) -> HloAnalysis:
    comps, entry = parse_computations(hlo_text)
    if entry is None:
        # Single unnamed module (tests): treat all lines as one computation.
        comps = {"__all__": [i for c in comps.values() for i in c]}
        entry = "__all__"
        if not comps["__all__"]:
            comps["__all__"] = []
            for line in hlo_text.splitlines():
                mi = _INSTR_RE.match(line)
                if mi:
                    comps["__all__"].append(
                        Instr(mi.group(1), mi.group(2), mi.group(3), mi.group(4))
                    )

    # Global shape table (instruction names are unique module-wide).
    shape_bytes: Dict[str, int] = {}
    for instrs in comps.values():
        for ins in instrs:
            shape_bytes[ins.name] = type_bytes(ins.type_str)
            # Parameters of subcomputations share names like param_0.1 —
            # fine, last one wins; sizes match call sites closely enough.

    # Call-graph multipliers. Fused computations are tracked separately:
    # their instructions are free for HBM accounting (internal to the
    # fusion) but dots inside them still count FLOPs at the call-site
    # multiplier (the CPU pipeline wraps most dots in kOutput fusions).
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    fused_mult: Dict[str, float] = defaultdict(float)
    for _ in range(8):
        changed = False
        for comp, instrs in comps.items():
            m = mult.get(comp, 0.0)
            if m <= 0:
                continue
            for ins in instrs:
                if ins.opcode == "while":
                    trip = 1
                    mt = _TRIP_RE.search(ins.tail)
                    if mt:
                        trip = int(mt.group(1))
                    for rex, factor in ((_BODY_RE, trip), (_COND_RE, trip + 1)):
                        mm = rex.search(ins.tail)
                        if mm:
                            tgt = mm.group(1)
                            new = m * factor
                            if abs(mult[tgt] - new) > 1e-9:
                                mult[tgt] = new
                                changed = True
                elif ins.opcode == "fusion":
                    mm = _CALLS_RE.search(ins.tail)
                    if mm:
                        tgt = mm.group(1)
                        if abs(fused_mult[tgt] - m) > 1e-9:
                            fused_mult[tgt] = m
                            changed = True
                elif ins.opcode in ("call", "conditional", "async-start"):
                    for mm in re.finditer(r"(?:calls|branch_computations)=\{?%?([\w.\-]+)", ins.tail):
                        tgt = mm.group(1)
                        if abs(mult[tgt] - m) > 1e-9:
                            mult[tgt] = m
                            changed = True
        if not changed:
            break
    fused = set(fused_mult)

    # Per-computation local shape tables (parameter names repeat across
    # computations; dot lhs lookups must be comp-local first).
    local_shapes: Dict[str, Dict[str, str]] = {
        c: {i.name: i.type_str for i in instrs} for c, instrs in comps.items()
    }
    global_types: Dict[str, str] = {}
    for instrs in comps.values():
        for i in instrs:
            global_types.setdefault(i.name, i.type_str)

    def dot_flops(comp: str, ins: Instr, tail: str) -> float:
        dims = _shape_dims(ins.type_str)
        prod_out = 1
        for d in dims:
            prod_out *= d
        k = 1
        mm = _LHS_CONTRACT_RE.search(ins.tail)
        if mm and mm.group(1):
            ops = _OPERAND_RE.findall(tail)
            lhs_dims: List[int] = []
            if ops:
                ts = local_shapes[comp].get(ops[0])
                if ts is None:
                    for lt in local_shapes.values():
                        if ops[0] in lt:
                            ts = lt[ops[0]]
                            break
                if ts:
                    lhs_dims = _shape_dims(ts)
            for idx in mm.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
        return 2.0 * prod_out * k

    def _elems(type_str: str) -> int:
        n = 0
        for m in _SHAPE_RE.finditer(type_str):
            if m.group(1) in ("token", "opaque"):
                continue
            e = 1
            if m.group(2):
                for d in m.group(2).split(","):
                    e *= int(d)
            n += e
        return n

    def _dtype_width(type_str: str) -> int:
        m = _SHAPE_RE.search(type_str)
        return _DTYPE_BYTES.get(m.group(1), 4) if m else 4

    def fusion_traffic(fcomp: str, call_tail: str, call_type: str) -> int:
        """HBM traffic of one fusion call under the output-driven (kLoop)
        model: a fusion computes each output element from O(1) elements of
        each operand, so reads ~= out_elems * operand_elem_width, capped at
        the full operand (slices of big stacked buffers read only the
        slice). dynamic-update-slice roots are in-place: traffic is the
        update region, not the whole buffer."""
        instrs = comps.get(fcomp)
        call_ops = _OPERAND_RE.findall(call_tail)
        out_bytes_full = type_bytes(call_type)
        out_elems = _elems(call_type)
        write_bytes = out_bytes_full
        if instrs:
            lshapes = local_shapes[fcomp]
            root = next((i2 for i2 in instrs if i2.is_root), None)
            if root is not None and root.opcode == "dynamic-update-slice":
                uops = _OPERAND_RE.findall(root.tail)
                upd_t = lshapes.get(uops[1], "") if len(uops) > 1 else ""
                upd = type_bytes(upd_t)
                if upd:
                    write_bytes = 2 * upd  # read + write the region
                    out_elems = _elems(upd_t)
        reads = 0
        for o in call_ops:
            t = global_types.get(o)
            if t:
                width = _dtype_width(t)
                full = type_bytes(t)
            else:
                width = 2
                full = shape_bytes.get(o, 0)
            reads += min(full, out_elems * width)
        return write_bytes + reads

    out = HloAnalysis()
    for comp, instrs in comps.items():
        in_fusion = comp in fused
        m = fused_mult.get(comp, 0.0) if in_fusion else mult.get(comp, 0.0)
        if m <= 0:
            continue
        for ins in instrs:
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done") or op.endswith("-update"):
                continue
            tail = ins.tail.split("calls=")[0].split("body=")[0]
            if op == "dot":
                flops = dot_flops(comp, ins, tail)
                out.flops += m * flops
                out.raw_flops += flops
            if in_fusion:
                continue  # bytes/collectives of fused internals are free
            obytes = shape_bytes.get(ins.name, type_bytes(ins.type_str))
            operand_bytes = sum(shape_bytes.get(o, 0) for o in _OPERAND_RE.findall(tail))
            if base in COLLECTIVE_OPS:
                b = operand_bytes or obytes
                out.collective_bytes += m * b
                out.raw_collective_bytes += b
                out.collective_bytes_by_op[base] += m * b
                out.collective_count_by_op[base] += 1
            if op in _FREE_OPS:
                continue
            if op == "fusion":
                mm = _CALLS_RE.search(ins.tail)
                traffic = fusion_traffic(mm.group(1) if mm else "", tail, ins.type_str)
            elif op in ("dynamic-slice", "slice"):
                traffic = 2 * obytes
            elif op == "dynamic-update-slice":
                ops_ = _OPERAND_RE.findall(tail)
                upd = shape_bytes.get(ops_[1], 0) if len(ops_) > 1 else 0
                traffic = 2 * upd
            elif op == "broadcast":
                traffic = obytes
            else:
                traffic = obytes + operand_bytes
            out.hbm_bytes += m * traffic
    return out


# Back-compat shim for the collective-only interface used by tests.
@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    count_by_op: Dict[str, int] = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> int:
        return int(sum(self.bytes_by_op.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.count_by_op.values()))

    def as_dict(self) -> Dict:
        return {
            "total_bytes": self.total_bytes,
            "total_count": self.total_count,
            "bytes_by_op": {k: int(v) for k, v in self.bytes_by_op.items()},
            "count_by_op": dict(self.count_by_op),
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Loop-weighted collective stats (kept as the public interface)."""
    a = analyze_hlo(hlo_text)
    st = CollectiveStats()
    for k, v in a.collective_bytes_by_op.items():
        st.bytes_by_op[k] = int(v)
    for k, v in a.collective_count_by_op.items():
        st.count_by_op[k] = v
    return st


# ----------------------------------------------------------------- roofline
# TPU v5e-class hardware constants (per the assignment).
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
) -> Dict[str, float]:
    """Three roofline terms in seconds (per device == per chip: the
    compiled module is the per-device SPMD program)."""
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1)
    return terms
