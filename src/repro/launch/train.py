"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b \
        --shape train_4k [--mesh single_pod|multi_pod|dev] [--steps N]

On real hardware this runs the same BuiltStep the dry-run compiles, over
the store-fed data pipeline, with checkpoint/restart and preemption
handling. On this container use --mesh dev (1 device) with a smoke config
(--smoke) — the code path is identical.

Fault tolerance in the loop:
  * async checkpoints every --ckpt-every steps, keep-3, atomic renames
  * --resume picks up the latest checkpoint (bitwise, tested)
  * SIGTERM (preemption notice) triggers a final checkpoint before exit
  * data pipeline workers lease/heartbeat/re-queue (repro.pipeline)
"""
from __future__ import annotations

import argparse
import signal
import sys
import tempfile
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llcysa-analytics-100m")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", choices=["single_pod", "multi_pod", "dev"], default="dev")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--zero1", action="store_true", default=True)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpointing import CheckpointManager
    from repro.configs.base import SHAPES, ShapeConfig
    from repro.core import EventStore, web_proxy_schema
    from repro.launch.mesh import make_dev_mesh, make_production_mesh
    from repro.launch.steps import build_train_step
    from repro.models import get_config, init_params
    from repro.pipeline import IngestWorkerPool, SyntheticWebProxySource
    from repro.pipeline.tokenizer import EventTokenizer
    from repro.training.optimizer import OptConfig, adamw_init

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.mesh == "dev":
        mesh = make_dev_mesh(1, 1)
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi_pod"))
    base = SHAPES[args.shape]
    shape = ShapeConfig(
        base.name,
        args.seq or (256 if args.smoke else base.seq_len),
        args.global_batch or (4 if args.smoke else base.global_batch),
        "train",
    )
    opt_cfg = OptConfig(total_steps=args.steps, compress_grads=args.compress_grads)
    built = build_train_step(cfg, mesh, shape, opt_cfg=opt_cfg, zero1=args.zero1)

    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M mesh={mesh.shape} "
          f"batch={shape.global_batch}x{shape.seq_len}")

    # Data: the paper's pipeline.
    src = SyntheticWebProxySource(seed=0)
    files = src.write_files(tempfile.mkdtemp(), 4, 4000, 0, 4 * 3600)
    store = EventStore(web_proxy_schema(), n_shards=4)
    pool = IngestWorkerPool(store, n_workers=2)
    for f in files:
        pool.submit_file(f)
    pool.drain()
    tok = EventTokenizer(store, vocab_size=cfg.vocab_size)
    batches = tok.sequences(0, 4 * 3600, seq_len=shape.seq_len + 1, batch=shape.global_batch)

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params, opt_cfg)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    mgr = CheckpointManager(ckpt_dir, keep=3)
    start = 0
    if args.resume and mgr.latest_step() is not None:
        start, params = mgr.restore_latest(params)
        print(f"resumed at step {start}")

    stop = {"now": False}

    def on_term(signum, frame):  # preemption notice
        stop["now"] = True

    signal.signal(signal.SIGTERM, on_term)

    t0 = time.perf_counter()
    for i in range(start, args.steps):
        raw = next(batches)
        batch = {"inputs": jnp.asarray(raw[:, :-1]), "targets": jnp.asarray(raw[:, 1:])}
        params, opt_state, metrics = built.fn(params, opt_state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            tps = shape.global_batch * shape.seq_len * (i - start + 1) / (time.perf_counter() - t0)
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} {tps:,.0f} tok/s")
        if (i + 1) % args.ckpt_every == 0 or stop["now"]:
            mgr.save(i + 1, params)
        if stop["now"]:
            print("preemption: checkpointed, exiting")
            break
    mgr.wait()
    print(f"checkpoints: {ckpt_dir}")


if __name__ == "__main__":
    main()
