"""Assemble the §Dry-run / §Roofline tables from experiments/dryrun JSONs.

    PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
                                                   [--baseline experiments/dryrun_baseline]

Prints markdown tables: per (arch x shape) single-pod roofline terms,
dominant bottleneck, useful-FLOP ratio, and (if --baseline) the
before/after deltas of the perf iterations.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

ARCH_ORDER = [
    "gemma2-9b", "internlm2-20b", "qwen1.5-4b", "gemma3-12b", "musicgen-medium",
    "moonshot-v1-16b-a3b", "phi3.5-moe-42b-a6.6b", "zamba2-2.7b",
    "llama-3.2-vision-11b", "mamba2-780m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(directory: str) -> Dict:
    out = {}
    for p in sorted(Path(directory).glob("*.json")):
        if p.name.startswith("FAIL"):
            continue
        r = json.loads(p.read_text())
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_s(x: float) -> str:
    return f"{x:.2e}"


def roofline_fraction(r: Dict) -> Optional[float]:
    """Useful-compute fraction of the step's roofline-limited time:
    MODEL_FLOPS-time / max(three terms). 1.0 = hardware-limit perfect."""
    t = r["roofline"]
    dom = max(t["compute_s"], t["memory_s"], t["collective_s"])
    if dom <= 0:
        return None
    from .hlo_analysis import PEAK_FLOPS

    useful = r["model_flops_per_device"] / PEAK_FLOPS
    return useful / dom


def table(results: Dict, mesh: str = "single_pod") -> List[str]:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bound | peak GiB "
        "| HLO GFLOP/dev | useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = results.get((arch, shape, mesh))
            if r is None:
                if shape == "long_500k":
                    lines.append(f"| {arch} | {shape} | — | — | — | skipped(full-attention) | — | — | — | — |")
                continue
            t = r["roofline"]
            frac = roofline_fraction(r)
            ratio = r.get("useful_flop_ratio")
            lines.append(
                f"| {arch} | {shape} | {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} "
                f"| {fmt_s(t['collective_s'])} | {t['bottleneck'].replace('_s','')} "
                f"| {r['memory']['peak_bytes']/2**30:.2f} "
                f"| {r['cost']['flops_per_device']/1e9:.1f} "
                f"| {(ratio if ratio else 0):.3f} | {(frac if frac else 0):.3f} |"
            )
    return lines


def _peak_new_formula(rec: Dict) -> float:
    """Recompute peak under the final formula (args + temps + non-aliased
    outputs) so baseline snapshots (recorded pre-donation, alias absent)
    compare like-for-like."""
    m = rec["memory"]
    alias = m.get("alias_bytes", 0)
    return (m["argument_bytes"] + m["temp_bytes"] + max(m["output_bytes"] - alias, 0)) / 2**30


def _collective_raw(rec: Dict) -> float:
    """Loop-once collective bytes — the metric the baseline snapshot
    recorded (the final records carry it as raw_bytes_loop_once)."""
    c = rec["collectives"]
    return float(c.get("raw_bytes_loop_once", c.get("total_bytes", 0.0)))


def _xla_flops(rec: Dict) -> float:
    return float(rec["cost"].get("xla_raw_flops", rec["cost"].get("flops_per_device", 0.0)))


def delta_table(results: Dict, baseline: Dict, cells: List) -> List[str]:
    lines = [
        "| cell | metric | baseline | optimized | delta |",
        "|---|---|---|---|---|",
    ]
    for (arch, shape, mesh) in cells:
        b = baseline.get((arch, shape, mesh))
        r = results.get((arch, shape, mesh))
        if not b or not r:
            continue
        # Like-for-like metrics only (the final analysis is loop-weighted;
        # the baseline snapshot is XLA-raw, so deltas use raw-vs-raw).
        for label, get in [
            ("peak GiB", _peak_new_formula),
            ("XLA flops/dev (loop-once)", _xla_flops),
            ("collective B/dev (loop-once)", _collective_raw),
        ]:
            b0, r0 = get(b), get(r)
            if not b0 and not r0:
                continue
            d = (r0 - b0) / b0 * 100 if b0 else 0.0
            lines.append(
                f"| {arch}/{shape}/{mesh} | {label} | {b0:.4g} | {r0:.4g} | {d:+.1f}% |"
            )
    return lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--mesh", default="single_pod")
    args = ap.parse_args()
    results = load(args.dir)
    print(f"## Roofline ({args.mesh}, {len(results)} cells loaded)\n")
    print("\n".join(table(results, args.mesh)))
    if args.baseline:
        baseline = load(args.baseline)
        cells = sorted({k for k in results} & {k for k in baseline})
        print("\n## Perf deltas vs baseline\n")
        print("\n".join(delta_table(results, baseline, cells)))


if __name__ == "__main__":
    main()
