"""Production mesh builders.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax init; a
module-level mesh would lock the device count prematurely).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods x 256
    chips as (pod=2, data=16, model=16) — the 'pod' axis extends data
    parallelism across the inter-pod (DCN-class) links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh for tests (requires XLA host-device override in a
    subprocess; see tests/test_distributed.py)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
