"""Production serving launcher: continuous batching + adaptive admission.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
        [--requests 32] [--max-batch 8] [--cache-len 256]

On TPU hardware the decode step is the same function the dry-run compiled
for the decode_32k cells; here it runs the smoke config on CPU.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llcysa-analytics-100m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.models import get_config, init_params
    from repro.serving import AdaptiveRequestBatcher, ServeEngine

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(
        cfg,
        params,
        max_batch=args.max_batch,
        cache_len=args.cache_len,
        batcher=AdaptiveRequestBatcher(max_batch=args.max_batch),
    )
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(
            rng.integers(0, cfg.vocab_size, int(rng.integers(4, 64))),
            max_new_tokens=args.max_new_tokens,
        )
    done = eng.run()
    ttft = sorted(r.ttft for r in done)
    lat = sorted(r.finished_at - r.submitted_at for r in done)
    n = len(done)
    print(f"served {n} requests; TTFT p50 {1e3*ttft[n//2]:.1f} ms, "
          f"p95 {1e3*ttft[int(0.95*(n-1))]:.1f} ms; E2E p50 {1e3*lat[n//2]:.1f} ms")
    print(f"adaptive admission k -> {eng.batcher.k:.1f}")


if __name__ == "__main__":
    main()
