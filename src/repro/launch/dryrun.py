import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import: jax locks the device count on first
# init, and the multi-pod dry-run needs 512 placeholder host devices.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
record memory / cost / collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi_pod --force

Results append to experiments/dryrun/<arch>__<shape>__<mesh>.json; already-
present cells are skipped unless --force (the full sweep takes a while on
one CPU core, so it is resumable)."""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import SHAPES
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.models import get_config, list_archs

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def plan_cells(arch_filter=None, shape_filter=None, mesh_filter=None):
    """The 40 assigned cells x 2 meshes, minus documented skips."""
    cells = []
    for arch in list_archs():
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if sname == "long_500k" and not cfg.sub_quadratic:
                continue  # full-attention arch: documented skip
            for mesh_kind in ("single_pod", "multi_pod"):
                if arch_filter and arch != arch_filter:
                    continue
                if shape_filter and sname != shape_filter:
                    continue
                if mesh_filter and mesh_kind != mesh_filter:
                    continue
                cells.append((arch, sname, mesh_kind))
    return cells


def run_cell(arch: str, shape_name: str, mesh_kind: str, opts=None) -> dict:
    """Lower + compile one cell; return the analysis record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi_pod"))
    n_chips = mesh.devices.size
    t0 = time.time()
    opts = dict(opts or {})
    if shape.kind == "train":
        # Megatron-style sequence parallelism: measured win on every train
        # cell (see EXPERIMENTS.md §Perf iteration 4).
        opts.setdefault("seq_parallel", True)
    built = build_step(cfg, mesh, shape, **opts)
    lowered = built.fn.lower(*built.abstract_args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = hlo_analysis.analyze_hlo(compiled.as_text())

    # Loop-weighted analysis (XLA's cost_analysis counts while bodies once
    # — see hlo_analysis docstring). flops: dot ops x trip counts (exact
    # for einsum-dominated models). hbm bytes: fusion-boundary traffic
    # upper bound; the lower bound is the argument working set read once.
    flops = float(hlo.flops)
    bytes_upper = float(hlo.hbm_bytes)
    bytes_lower = float(mem.argument_size_in_bytes)
    terms = hlo_analysis.roofline_terms(flops, bytes_upper, hlo.collective_bytes)
    terms["memory_lower_s"] = bytes_lower / hlo_analysis.HBM_BW

    # Useful-FLOPs baseline: 6*N*D train / 2*N per decoded token.
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else 1)
    if shape.kind == "train":
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        model_flops = 2 * n_active * shape.global_batch * shape.seq_len
    else:
        model_flops = 2 * n_active * tokens
    model_flops_per_dev = model_flops / n_chips

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "n_chips": int(n_chips),
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "params": int(n_params),
        "active_params": int(n_active),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            # args + temps + non-aliased outputs (donated buffers alias).
            "peak_bytes": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes
            + max(mem.output_size_in_bytes - mem.alias_size_in_bytes, 0),
        },
        "cost": {
            "flops_per_device": flops,
            "bytes_per_device": bytes_upper,
            "bytes_lower_per_device": bytes_lower,
            "xla_raw_flops": float(cost.get("flops", 0.0)),
            "xla_raw_bytes": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives": {
            "total_bytes": hlo.collective_bytes,
            "bytes_by_op": dict(hlo.collective_bytes_by_op),
            "count_by_op": dict(hlo.collective_count_by_op),
            "raw_bytes_loop_once": hlo.raw_collective_bytes,
        },
        "roofline": terms,
        "model_flops_per_device": model_flops_per_dev,
        "useful_flop_ratio": (model_flops_per_dev / flops) if flops else None,
    }


def run_store_cell(mesh_kind: str, rows_per_tablet: int = 4_000_000) -> dict:
    """Extra (beyond the 40 assigned cells): the paper's OWN system on the
    production mesh — the distributed tablet scan (filter + count + top-k)
    lowered and compiled with every chip acting as a tablet server.
    4M rows x 12 fields/tablet = ~1B rows (~200 GB columnar) single-pod."""
    from repro.core import And, Eq, Not, web_proxy_schema, EventStore
    from repro.core.dist_query import build_scan_step, dist_store_shapes
    from repro.core.filter import compile_tree
    from repro.kernels.filter_scan.ops import pad_program

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi_pod"))
    n_chips = mesh.devices.size
    store = EventStore(web_proxy_schema(), n_shards=4)  # schema carrier
    store.ingest(
        [0, 1], {"domain": ["a.com", "b.com"], "method": ["GET", "POST"], "status": ["200", "404"]}
    )
    tree = And(Eq("domain", "a.com"), Not(Eq("status", "404")))
    prog = compile_tree(store, tree)
    opc, a0, a1, cs = pad_program(prog)
    shapes = dist_store_shapes(mesh, rows_per_tablet, store.schema.n_fields)
    step = build_scan_step(mesh, store.schema.n_fields, len(opc), cs.shape)
    import jax.numpy as jnp

    t0 = time.time()
    lowered = step.lower(
        shapes["rev_ts"], shapes["cols"], shapes["counts"],
        jax.ShapeDtypeStruct(opc.shape, jnp.int32), jax.ShapeDtypeStruct(a0.shape, jnp.int32),
        jax.ShapeDtypeStruct(a1.shape, jnp.int32), jax.ShapeDtypeStruct(cs.shape, jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32), jax.ShapeDtypeStruct((), jnp.int32),
    )
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    hlo = hlo_analysis.analyze_hlo(compiled.as_text())
    terms = hlo_analysis.roofline_terms(hlo.flops, hlo.hbm_bytes, hlo.collective_bytes)
    return {
        "arch": "llcysa-store",
        "shape": f"scan_{rows_per_tablet * n_chips // 10**6}M_rows",
        "mesh": mesh_kind,
        "n_chips": int(n_chips),
        "kind": "scan",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + max(mem.output_size_in_bytes - mem.alias_size_in_bytes, 0),
        },
        "cost": {"flops_per_device": hlo.flops, "bytes_per_device": hlo.hbm_bytes},
        "collectives": {
            "total_bytes": hlo.collective_bytes,
            "bytes_by_op": dict(hlo.collective_bytes_by_op),
        },
        "roofline": terms,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single_pod", "multi_pod"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--zero1", action="store_true", default=True)
    ap.add_argument("--store-cells", action="store_true", help="run ONLY the extra llcysa-store cells")
    args = ap.parse_args()

    if args.store_cells:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        for mesh_kind in ("single_pod", "multi_pod"):
            rec = run_store_cell(mesh_kind)
            out = RESULTS_DIR / f"llcysa-store__{rec['shape']}__{mesh_kind}.json"
            out.write_text(json.dumps(rec, indent=1))
            r = rec["roofline"]
            print(
                f"OK  llcysa-store {rec['shape']} {mesh_kind} compile={rec['compile_s']:.1f}s "
                f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB "
                f"comp={r['compute_s']:.2e}s mem={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s",
                flush=True,
            )
        return

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    cells = plan_cells(args.arch, args.shape, args.mesh)
    print(f"dry-run: {len(cells)} cells on {len(jax.devices())} host devices")
    n_ok = n_skip = n_fail = 0
    for arch, sname, mesh_kind in cells:
        out = RESULTS_DIR / f"{arch}__{sname}__{mesh_kind}.json"
        if out.exists() and not args.force:
            n_skip += 1
            continue
        try:
            rec = run_cell(arch, sname, mesh_kind)
            out.write_text(json.dumps(rec, indent=1))
            r = rec["roofline"]
            print(
                f"OK  {arch:22s} {sname:12s} {mesh_kind:10s} "
                f"compile={rec['compile_s']:7.1f}s peak={rec['memory']['peak_bytes']/2**30:6.2f}GiB "
                f"comp={r['compute_s']:.2e}s mem={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s "
                f"bound={r['bottleneck']}",
                flush=True,
            )
            n_ok += 1
        except Exception as e:  # noqa: BLE001 — a failing cell is a bug; record it
            n_fail += 1
            err = {"arch": arch, "shape": sname, "mesh": mesh_kind, "error": repr(e),
                   "traceback": traceback.format_exc()}
            (RESULTS_DIR / f"FAIL__{arch}__{sname}__{mesh_kind}.json").write_text(
                json.dumps(err, indent=1)
            )
            print(f"FAIL {arch} {sname} {mesh_kind}: {e!r}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} failed={n_fail}")


if __name__ == "__main__":
    main()
