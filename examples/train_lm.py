"""End-to-end training driver: events -> store -> tokens -> LM.

Trains the LLCySA analytics LM (next-event prediction) on tokenized web
proxy events drawn from the sharded store, with checkpointing + resume.

    PYTHONPATH=src python examples/train_lm.py                 # CPU-sized
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is the real ~100M-parameter config (configs/llcysa.py);
the default 'mini' preset shrinks it so the example finishes in minutes on
this container's single CPU core. Both run the identical code path.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import CheckpointManager
from repro.core import EventStore, web_proxy_schema
from repro.models import get_config, init_params
from repro.models.model import forward_train
from repro.pipeline import IngestWorkerPool, SyntheticWebProxySource
from repro.pipeline.tokenizer import EventTokenizer
from repro.training.optimizer import OptConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["mini", "100m"], default="mini")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config("llcysa-analytics-100m")
    if args.preset == "mini":
        cfg = cfg.replace(n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, d_ff=768)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params, preset={args.preset})")

    # --- the paper's pipeline feeds training ---
    print("staging + ingesting events ...")
    src = SyntheticWebProxySource(seed=3)
    import tempfile

    files = src.write_files(tempfile.mkdtemp(), 8, 8000, 0, 8 * 3600)
    store = EventStore(web_proxy_schema(), n_shards=4)
    pool = IngestWorkerPool(store, n_workers=2)
    for f in files:
        pool.submit_file(f)
    pool.drain()
    print(f"store: {store.total_rows} events")

    tok = EventTokenizer(store, vocab_size=cfg.vocab_size)
    batches = tok.sequences(0, 8 * 3600, seq_len=args.seq + 1, batch=args.batch)

    opt_cfg = OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = adamw_init(params, opt_cfg)
    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    start_step = 0
    if args.resume and mgr.latest_step() is not None:
        start_step, params = mgr.restore_latest(params)
        print(f"resumed from step {start_step}")

    @jax.jit
    def step(p, s, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: forward_train(pp, cfg, b, remat=False), has_aux=True
        )(p)
        p, s, om = adamw_update(p, grads, s, opt_cfg)
        return p, s, loss, om["grad_norm"]

    t0 = time.perf_counter()
    tokens_seen = 0
    for i in range(start_step, args.steps):
        raw = next(batches)
        batch = {
            "inputs": jnp.asarray(raw[:, :-1]),
            "targets": jnp.asarray(raw[:, 1:]),
        }
        params, state, loss, gnorm = step(params, state, batch)
        tokens_seen += args.batch * args.seq
        if i % 10 == 0 or i == args.steps - 1:
            dt = time.perf_counter() - t0
            print(
                f"step {i:4d}  loss {float(loss):.4f}  |g| {float(gnorm):.3f}  "
                f"{tokens_seen / max(dt, 1e-9):,.0f} tok/s"
            )
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, params)
    mgr.wait()
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
