"""The full LLCySA-style situational-awareness loop, end to end:

  1. stage raw web-proxy logs on the 'central filesystem'
  2. master queue + parallel ingest workers -> sharded 3-table store
     (with a simulated worker failure: the lease expires and re-queues)
  3. analyst queries via the planner + adaptive batching
  4. events -> tokens -> train the analytics LM a few steps
  5. score a suspicious traffic window by LM perplexity (the 'analytic')

    PYTHONPATH=src python examples/cyber_pipeline.py
"""
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import And, Eq, EventStore, QueryProcessor, QueryStats, web_proxy_schema
from repro.models import get_config, init_params
from repro.models.model import forward_train
from repro.pipeline import IngestWorkerPool, SyntheticWebProxySource
from repro.pipeline.tokenizer import EventTokenizer
from repro.training.optimizer import OptConfig, adamw_init, adamw_update


def main():
    print("== 1. stage raw logs ==")
    src = SyntheticWebProxySource(seed=11)
    staged = src.write_files(tempfile.mkdtemp(), n_files=6, lines_per_file=5000, t_start=0, t_stop=4 * 3600)
    print(f"   {len(staged)} files staged")

    print("== 2. parallel ingest (with a mid-run worker failure) ==")
    store = EventStore(web_proxy_schema(), n_shards=4)
    # Lease timeout must comfortably exceed the heartbeat period, or live
    # workers' files re-queue (at-least-once semantics -> duplicates).
    pool = IngestWorkerPool(store, n_workers=3, lease_timeout_s=10.0)
    pool.kill_worker(0)  # node failure: its lease will expire + re-queue
    t0 = time.perf_counter()
    for f in staged:
        pool.submit_file(f)
    reports = pool.drain()
    dt = time.perf_counter() - t0
    print(f"   {store.total_rows} events in {dt:.1f}s despite 1 dead worker "
          f"({sum(r.files for r in reports)} files completed)")
    assert store.total_rows == 30_000

    print("== 3. analyst queries (planner + adaptive batching) ==")
    qp = QueryProcessor(store)
    dom = src.domain_by_popularity(0.02)
    q = And(Eq("domain", dom), Eq("status", "404"))
    stats = QueryStats()
    rows = sum(b.n for b in qp.run_scheme("batched_index", 0, 4 * 3600, q, stats=stats))
    print(f"   {dom} 404s: {rows} rows in {stats.batches} adaptive batches; plan: {stats.plan.describe()}")

    print("== 4. train the analytics LM on the event stream ==")
    cfg = get_config("llcysa-analytics-100m", smoke=True)
    tok = EventTokenizer(store, vocab_size=cfg.vocab_size)
    it = tok.sequences(0, 4 * 3600, seq_len=129, batch=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=5, total_steps=30)
    state = adamw_init(params, opt_cfg)

    @jax.jit
    def step(p, s, b):
        (loss, _), grads = jax.value_and_grad(
            lambda pp: forward_train(pp, cfg, b, remat=False), has_aux=True
        )(p)
        p, s, _ = adamw_update(p, grads, s, opt_cfg)
        return p, s, loss

    losses = []
    for i in range(30):
        raw = next(it)
        params, state, loss = step(
            params, state, {"inputs": jnp.asarray(raw[:, :-1]), "targets": jnp.asarray(raw[:, 1:])}
        )
        losses.append(float(loss))
    print(f"   loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")

    print("== 5. anomaly scoring: LM surprise per traffic window ==")

    @jax.jit
    def nll(p, b):
        return forward_train(p, cfg, b, remat=False)[0]

    scores = []
    for w0 in range(0, 4 * 3600, 3600):
        raw = next(tok.sequences(w0, w0 + 3600, seq_len=129, batch=2, seed=w0))
        s = float(nll(params, {"inputs": jnp.asarray(raw[:, :-1]), "targets": jnp.asarray(raw[:, 1:])}))
        scores.append((w0 // 3600, s))
    for h, s in scores:
        bar = "#" * int((s - min(x for _, x in scores)) * 40 + 1)
        print(f"   hour {h}: surprise {s:.3f} {bar}")
    print("pipeline complete.")


if __name__ == "__main__":
    main()
