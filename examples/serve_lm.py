"""Serving demo: continuous batching with the paper's adaptive admission.

Submits a burst of requests with mixed prompt lengths and prints per-
request TTFT plus the batcher's admission trajectory — watch k grow
geometrically (c = 1.5) while rounds stay inside [T_min, T_max], the
transplanted Algorithm 1.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.models import get_config, init_params
from repro.serving import AdaptiveRequestBatcher, ServeEngine


def main():
    cfg = get_config("llcysa-analytics-100m", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batcher = AdaptiveRequestBatcher(k0=1, c=1.5, t_min=0.02, t_max=0.25, max_batch=8)
    eng = ServeEngine(cfg, params, max_batch=8, cache_len=128, batcher=batcher)

    rng = np.random.default_rng(0)
    n_req = 24
    for i in range(n_req):
        eng.submit(
            rng.integers(0, cfg.vocab_size, int(rng.integers(4, 48))),
            max_new_tokens=int(rng.integers(4, 16)),
        )
    done = eng.run()

    print(f"served {len(done)}/{n_req} requests")
    ttfts = sorted(r.ttft for r in done)
    print(f"TTFT p50={1e3*ttfts[len(ttfts)//2]:.1f} ms  p95={1e3*ttfts[int(0.95*len(ttfts))]:.1f} ms")
    print("\nadmission rounds (round_time_s, served):")
    for t, served in batcher.history[:16]:
        print(f"  {t:7.3f}s  batch={served}")
    print(f"final adaptive k = {batcher.k:.1f}")


if __name__ == "__main__":
    main()
