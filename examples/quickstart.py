"""Quickstart: the paper's pipeline in 60 seconds.

Builds a sharded event store, ingests synthetic web-proxy traffic, runs
the same query four ways (Scan / Batched Scan / Index / Batched Index —
paper §IV-B), then answers an aggregation with the server-side iterator
stack (fused filter+combine kernel) — per-group partials instead of rows.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import (
    AggregateSpec,
    And,
    Eq,
    EventStore,
    QueryProcessor,
    QueryStats,
    web_proxy_schema,
)
from repro.core.ingest import BatchWriter
from repro.pipeline.sources import SyntheticWebProxySource, parse_web_proxy_lines


def main():
    print("== build store (8 shards, as the paper's 8-node instance) ==")
    store = EventStore(web_proxy_schema(), n_shards=8)
    src = SyntheticWebProxySource(seed=1)
    writer = BatchWriter(store, batch_rows=8192)
    t0 = time.perf_counter()
    n = 60_000
    lines = src.gen_lines(n, 0, 4 * 3600)
    ts, cols = parse_web_proxy_lines(lines)
    writer.add(ts, cols, nbytes=sum(len(l) for l in lines))
    writer.close()
    store.flush_all()
    store.compact_all()
    dt = time.perf_counter() - t0
    print(f"ingested {n} events in {dt:.1f}s ({n/dt:,.0f} rows/s)\n")

    popular = src.domain_by_popularity(0.0)
    rare = src.domain_by_popularity(0.15)
    query = And(Eq("domain", popular), Eq("method", "GET"))
    print(f"query: domain={popular} AND method=GET over 4h of traffic")

    qp = QueryProcessor(store)
    for scheme in ["scan", "batched_scan", "index", "batched_index"]:
        stats = QueryStats()
        t0 = time.perf_counter()
        first = None
        rows = 0
        for blk in qp.run_scheme(scheme, 0, 4 * 3600, query, stats=stats):
            if first is None:
                first = time.perf_counter() - t0
            rows += blk.n
        total = time.perf_counter() - t0
        plan = stats.plan.describe() if stats.plan else "?"
        print(
            f"  {scheme:14s} first={1e3*(first or 0):8.2f} ms  total={1e3*total:8.2f} ms  "
            f"rows={rows}  batches={stats.batches}  plan={plan}"
        )

    print("\naggregation: count matching events per method per hour (iterator stack)")
    spec = AggregateSpec(group_by=("method",), op="count", time_bucket_s=3600)
    t0 = time.perf_counter()
    res = qp.aggregate(spec, 0, 4 * 3600, query)
    total = time.perf_counter() - t0
    shipped = res.gids.nbytes + res.values.nbytes + res.counts.nbytes
    print(
        f"  combine_scan   total={1e3*total:8.2f} ms  groups={res.n_groups}  "
        f"rows_combined={res.total_matched()}  client_bytes~{shipped}"
    )
    for row in sorted(res.rows(store), key=lambda r: r["bucket_ts"])[:4]:
        print(f"    {row['method']:5s} hour={row['bucket_ts']//3600}  count={row['value']}")


if __name__ == "__main__":
    main()
