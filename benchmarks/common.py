"""Shared benchmark fixtures: a populated store + the paper's Query A/B/C
selectivity tiers, plus the canonical-artifact emitter every bench uses
to write its checked-in BENCH_*.json."""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import EventStore, web_proxy_schema
from repro.core.ingest import BatchWriter, IngestMetrics
from repro.pipeline.sources import SyntheticWebProxySource, parse_web_proxy_lines

FOUR_HOURS = 4 * 3600

ARTIFACT_SCHEMA_VERSION = 1


def artifact_path(name: str) -> str:
    return os.path.join(os.path.dirname(__file__), f"BENCH_{name}.json")


def write_artifact(name: str, payload: dict) -> str:
    """Write benchmarks/BENCH_<name>.json — the canonical checked-in perf
    artifact shape (schema_version + kind + the bench's own payload).
    Stable formatting (sorted keys, trailing newline) so regenerating an
    unchanged result produces a zero diff."""
    doc = {"schema_version": ARTIFACT_SCHEMA_VERSION, "kind": f"bench_{name}"}
    doc.update(payload)
    path = artifact_path(name)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def measured(times: Sequence[float], warmup: int = 1) -> List[float]:
    """Drop the first `warmup` iterations (first-trace XLA compiles) from
    a timing series so percentile columns aren't polluted by compile
    time. Keeps at least one sample."""
    times = list(times)
    if len(times) > warmup:
        return times[warmup:]
    return times[-1:] if times else []


def time_stats(times: Sequence[float], warmup: int = 1) -> Dict[str, float]:
    """median/p95/min/max/mean over the post-warmup samples."""
    kept = measured(times, warmup=warmup)
    if not kept:
        return {"n": 0}
    arr = np.asarray(kept, dtype=np.float64)
    return {
        "n": int(arr.size),
        "mean_s": float(arr.mean()),
        "median_s": float(np.median(arr)),
        "p95_s": float(np.percentile(arr, 95)),
        "min_s": float(arr.min()),
        "max_s": float(arr.max()),
    }


@dataclass
class BenchStore:
    store: EventStore
    source: SyntheticWebProxySource
    t_start: int
    t_stop: int
    n_rows: int


def build_bench_store(
    n_rows: int = 120_000,
    n_shards: int = 8,
    t_stop: int = FOUR_HOURS,
    seed: int = 3,
    flush_rows: int = 32768,
) -> BenchStore:
    """Ingest n_rows of synthetic web-proxy traffic over a 4-hour window
    (the paper's query experiments use a 4-hour range of web traffic)."""
    src = SyntheticWebProxySource(seed=seed)
    store = EventStore(web_proxy_schema(), n_shards=n_shards, flush_rows=flush_rows)
    writer = BatchWriter(store, batch_rows=8192)
    chunk = 20_000
    for i in range(0, n_rows, chunk):
        n = min(chunk, n_rows - i)
        lines = src.gen_lines(n, 0, t_stop)
        ts, cols = parse_web_proxy_lines(lines)
        writer.add(ts, cols, nbytes=sum(len(l) for l in lines))
    writer.close()
    store.flush_all()
    store.compact_all()
    return BenchStore(store, src, 0, t_stop, n_rows)


def paper_queries(bs: BenchStore) -> Dict[str, str]:
    """Query A: most popular domain; B: somewhat popular; C: unpopular —
    matching the paper's selectivity tiers. The C pick is the least popular
    domain that still has >= ~50 hits so 'time to 100th entry' is
    measurable."""
    from repro.core import Eq, QueryProcessor

    counts = {}
    for q in np.linspace(0, 0.5, 100):
        dom = bs.source.domain_by_popularity(q)
        c = bs.store.agg_count("domain", dom, bs.t_start, bs.t_stop)
        counts[dom] = c
    ranked = sorted(counts.items(), key=lambda kv: -kv[1])
    top = ranked[0][1]
    a = ranked[0][0]
    b = next(
        (d for d, c in ranked if c <= top * 0.15 and c > max(top * 0.02, 100)),
        ranked[len(ranked) // 4][0],
    )
    c = next((d for d, c in reversed(ranked) if c >= 30), ranked[-1][0])
    return {"A": a, "B": b, "C": c}


def timed(fn, *args, **kw) -> Tuple[float, object]:
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return time.perf_counter() - t0, out
