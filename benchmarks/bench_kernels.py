"""Kernel microbenchmarks: throughput of the three store kernels (jnp
reference backend — the production CPU path; Pallas runs interpret-mode on
CPU and is validated for correctness in tests, not raced here)."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import And, Eq, EventStore, Not, Or, web_proxy_schema
from repro.core.filter import compile_tree
from repro.kernels.aggregate_combine import combine_sorted_counts
from repro.kernels.combine_scan import combine_scan
from repro.kernels.filter_scan import filter_scan
from repro.kernels.merge_intersect import intersect_sorted
from repro.kernels.merge_runs import merge_sorted_runs


def run(n: int = 500_000) -> Dict:
    """n: event count — pass something small (e.g. 50_000) for CI smoke."""
    rng = np.random.default_rng(5)
    store = EventStore(web_proxy_schema(), n_shards=1)
    vals = {
        "domain": rng.choice(["a.com", "b.com", "c.com", "d.com"], size=n).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404", "500"], size=n).tolist(),
    }
    ts = np.sort(rng.integers(0, 3600, n))
    cols = store.encode_events(ts, vals)
    tree = And(Or(Eq("domain", "a.com"), Eq("domain", "b.com")), Not(Eq("status", "404")))
    prog = compile_tree(store, tree)
    filter_scan(cols[:1024], prog)  # warm jit
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        mask = filter_scan(cols, prog)
    dt_f = (time.perf_counter() - t0) / reps

    a = np.unique(rng.integers(0, 1 << 52, max(n * 4 // 5, 1024)).astype(np.int64))
    b = np.unique(
        np.concatenate([
            rng.choice(a, max(n // 10, 16), replace=False),
            rng.integers(0, 1 << 52, max(n * 2 // 5, 512)).astype(np.int64),
        ])
    )
    intersect_sorted(a[:1024], b[:1024])
    t0 = time.perf_counter()
    for _ in range(reps):
        inter = intersect_sorted(a, b)
    dt_i = (time.perf_counter() - t0) / reps

    keys = np.sort(rng.integers(0, max(n // 20, 8), 2 * n).astype(np.int64))
    cnt = rng.integers(1, 4, 2 * n).astype(np.int32)
    combine_sorted_counts(keys[:1024], cnt[:1024])
    t0 = time.perf_counter()
    for _ in range(reps):
        uk, uc = combine_sorted_counts(keys, cnt)
    dt_c = (time.perf_counter() - t0) / reps

    # Fused filter+combine (the iterator stack's terminal dispatch) vs the
    # same work as two passes — the reason combine_scan exists.
    gfid = store.schema.field_id("method")
    gids = cols[:, gfid].astype(np.int64)
    order = np.argsort(gids, kind="stable")
    gids_s, cols_s = gids[order], cols[order]
    combine_scan(gids_s[:1024], None, cols_s[:1024], prog)  # warm jit
    t0 = time.perf_counter()
    for _ in range(reps):
        combine_scan(gids_s, None, cols_s, prog, op="count")
    dt_fc = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        m = filter_scan(cols_s, prog)
        k = gids_s[m]
        combine_sorted_counts(k, np.ones(len(k), np.int32))
    dt_2p = (time.perf_counter() - t0) / reps

    # k-way sorted-run merge (major compaction) vs the retired placeholder
    # (jitted concatenate + argsort — tables.py's former _merge_runs).
    import jax
    import jax.numpy as jnp

    @jax.jit
    def _concat_sort(keys_list, cols_list):
        keys = jnp.concatenate(keys_list)
        cc = jnp.concatenate(cols_list)
        order = jnp.argsort(keys)
        return keys[order], cc[order]

    k_runs = 6
    per = max(n // k_runs, 256)
    runs = []
    for _ in range(k_runs):
        rk = np.sort(rng.integers(0, 1 << 52, per).astype(np.int64))
        runs.append((rk, rng.integers(0, 100, (per, 4)).astype(np.int32)))
    merge_sorted_runs(runs)  # warm jit at the timed shapes
    t0 = time.perf_counter()
    for _ in range(reps):
        mk, mc = merge_sorted_runs(runs)
    dt_m = (time.perf_counter() - t0) / reps
    # Warm at the timed shapes too — _concat_sort is shape-specialized and
    # a cold first rep would bill its compile to the baseline.
    jax.block_until_ready(
        _concat_sort([jnp.asarray(kk) for kk, _ in runs], [jnp.asarray(c) for _, c in runs])[0]
    )
    t0 = time.perf_counter()
    for _ in range(reps):
        ck, _ = _concat_sort(
            [jnp.asarray(kk) for kk, _ in runs], [jnp.asarray(c) for _, c in runs]
        )
        jax.block_until_ready(ck)
    dt_cs = (time.perf_counter() - t0) / reps

    return {
        "filter_rows_per_s": len(cols) / dt_f,
        "filter_us": dt_f * 1e6,
        "intersect_keys_per_s": len(a) / dt_i,
        "intersect_us": dt_i * 1e6,
        "combine_rows_per_s": len(keys) / dt_c,
        "combine_us": dt_c * 1e6,
        "combine_scan_rows_per_s": len(cols) / dt_fc,
        "combine_scan_us": dt_fc * 1e6,
        "combine_scan_two_pass_us": dt_2p * 1e6,
        "merge_runs_rows_per_s": k_runs * per / dt_m,
        "merge_runs_us": dt_m * 1e6,
        "merge_runs_concat_sort_us": dt_cs * 1e6,
    }


def emit_csv(res: Dict) -> List[str]:
    return [
        f"kernel_filter_scan,{res['filter_us']:.0f},rows_per_s={res['filter_rows_per_s']:.3g}",
        f"kernel_merge_intersect,{res['intersect_us']:.0f},keys_per_s={res['intersect_keys_per_s']:.3g}",
        f"kernel_aggregate_combine,{res['combine_us']:.0f},rows_per_s={res['combine_rows_per_s']:.3g}",
        f"kernel_combine_scan_fused,{res['combine_scan_us']:.0f},rows_per_s={res['combine_scan_rows_per_s']:.3g}",
        f"kernel_combine_scan_two_pass,{res['combine_scan_two_pass_us']:.0f},baseline=separate_filter_then_combine",
        f"kernel_merge_runs,{res['merge_runs_us']:.0f},rows_per_s={res['merge_runs_rows_per_s']:.3g}",
        f"kernel_merge_runs_concat_sort,{res['merge_runs_concat_sort_us']:.0f},baseline=retired_placeholder",
    ]

def emit_json(res: Dict) -> Dict:
    """Canonical artifact (BENCH_kernels.json via benchmarks/run.py):
    per-kernel microseconds and throughput, rounded for stable diffs."""
    return {
        "schema_version": 1,
        "benchmark": "kernels",
        "kernels": {k: round(float(v), 2) for k, v in sorted(res.items())},
    }
