"""Kernel microbenchmarks: throughput of the three store kernels (jnp
reference backend — the production CPU path; Pallas runs interpret-mode on
CPU and is validated for correctness in tests, not raced here)."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import And, Eq, EventStore, Not, Or, web_proxy_schema
from repro.core.filter import compile_tree
from repro.kernels.aggregate_combine import combine_sorted_counts
from repro.kernels.filter_scan import filter_scan
from repro.kernels.merge_intersect import intersect_sorted


def run() -> Dict:
    rng = np.random.default_rng(5)
    store = EventStore(web_proxy_schema(), n_shards=1)
    n = 500_000
    vals = {
        "domain": rng.choice(["a.com", "b.com", "c.com", "d.com"], size=n).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404", "500"], size=n).tolist(),
    }
    ts = np.sort(rng.integers(0, 3600, n))
    cols = store.encode_events(ts, vals)
    tree = And(Or(Eq("domain", "a.com"), Eq("domain", "b.com")), Not(Eq("status", "404")))
    prog = compile_tree(store, tree)
    filter_scan(cols[:1024], prog)  # warm jit
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        mask = filter_scan(cols, prog)
    dt_f = (time.perf_counter() - t0) / reps

    a = np.unique(rng.integers(0, 1 << 52, 400_000).astype(np.int64))
    b = np.unique(
        np.concatenate([rng.choice(a, 50_000, replace=False), rng.integers(0, 1 << 52, 200_000).astype(np.int64)])
    )
    intersect_sorted(a[:1024], b[:1024])
    t0 = time.perf_counter()
    for _ in range(reps):
        inter = intersect_sorted(a, b)
    dt_i = (time.perf_counter() - t0) / reps

    keys = np.sort(rng.integers(0, 50_000, 1_000_000).astype(np.int64))
    cnt = rng.integers(1, 4, 1_000_000).astype(np.int32)
    combine_sorted_counts(keys[:1024], cnt[:1024])
    t0 = time.perf_counter()
    for _ in range(reps):
        uk, uc = combine_sorted_counts(keys, cnt)
    dt_c = (time.perf_counter() - t0) / reps

    return {
        "filter_rows_per_s": len(cols) / dt_f,
        "filter_us": dt_f * 1e6,
        "intersect_keys_per_s": len(a) / dt_i,
        "intersect_us": dt_i * 1e6,
        "combine_rows_per_s": len(keys) / dt_c,
        "combine_us": dt_c * 1e6,
    }


def emit_csv(res: Dict) -> List[str]:
    return [
        f"kernel_filter_scan,{res['filter_us']:.0f},rows_per_s={res['filter_rows_per_s']:.3g}",
        f"kernel_merge_intersect,{res['intersect_us']:.0f},keys_per_s={res['intersect_keys_per_s']:.3g}",
        f"kernel_aggregate_combine,{res['combine_us']:.0f},rows_per_s={res['combine_rows_per_s']:.3g}",
    ]
