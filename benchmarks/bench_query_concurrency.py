"""Latency vs concurrent clients — the paper's query experiments, plural.

The paper measures query performance as "latency of the client receiving
initial result sets" with clients querying WHILE the database ingests
(§IV-B/§V); the D4M follow-up (arXiv:1406.4923) scales by multiplying
client processes against shared tablet servers. This benchmark drives the
serve plane (repro.serve_db.QueryService) the same way: N concurrent
sessions, each streaming a fixed mix of paper-style queries against ONE
shared live DistIngestPlane, at N = 1 / 2 / 4 / 8 — once at rest and once
with a concurrent ingest writer — reporting per-session time-to-first-
result (the Table I metric) and queue wait.

Reproduction targets (validate()):
  - no starvation: at 4 concurrent sessions every session's median TTFR
    stays within 3x its solo-session value (the TTFR-priority scheduler's
    whole job);
  - exactness under concurrency: every session's counts equal the
    single-caller host oracle (rest rounds; ingest rounds bound-checked
    between the before/after oracles since each query pins a snapshot);
  - compaction stays off the query path: the background compactor ran
    >= 1 fold during the sweep and every fold in
    telemetry()["fold_events"] is attributed to a non-query source.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

from repro.core import Eq, EventStore, QueryProcessor, web_proxy_schema
from repro.core.dist_ingest import DistBatchWriter, DistIngestPlane
from repro.pipeline.sources import SyntheticWebProxySource, parse_web_proxy_lines
from repro.serve_db import QueryService

FOUR_HOURS = 4 * 3600
SESSIONS = (1, 2, 4, 8)


def _build(n_rows: int, seed: int = 41):
    """Host store + live plane with the same rows (the host is the
    oracle), plus a reserve of parsed-but-uningested rows for the
    concurrent-ingest rounds."""
    from repro.launch.mesh import make_dev_mesh

    src = SyntheticWebProxySource(seed=seed)
    reserve = n_rows  # up to n_rows more arrive during ingest rounds
    lines = src.gen_lines(n_rows + reserve, 0, FOUR_HOURS)
    ts, cols = parse_web_proxy_lines(lines)
    store = EventStore(web_proxy_schema(), n_shards=4, flush_rows=32768)
    head = {k: v[:n_rows] for k, v in cols.items()}
    store.ingest(ts[:n_rows], head)
    store.flush_all()
    store.compact_all()
    plane = DistIngestPlane.for_store(
        store,
        make_dev_mesh(1, 1),
        capacity=n_rows + reserve + 8192,
        tablets_per_device=2,
        mem_rows=2048,
        max_runs=6,
        append_rows=1024,
    )
    w = DistBatchWriter(store, plane, batch_rows=8192)
    w.add(ts[:n_rows], head)
    w.close()
    # Warm every one-time XLA compile a live sweep would otherwise hit
    # mid-measurement (what a serving deployment does at startup): the
    # seal program at every fill bucket, and all three compaction
    # programs — minor, incremental fold step, full major. A cold
    # compile is seconds; it would land inside some session's TTFR (or
    # inside one "bounded" compaction increment).
    plane.warm_seal()
    plane.warm_compaction()
    return store, plane, src, (ts, cols, n_rows)


def _paper_mix(store, src) -> List[Dict]:
    """Query mix per session: the paper's A/B/C selectivity tiers (most /
    somewhat / un-popular domain), each under the winning batched_index
    scheme plus a batched_scan on B — four streamed queries per session
    pass."""
    counts = {}
    for q in np.linspace(0, 0.5, 60):
        dom = src.domain_by_popularity(q)
        counts[dom] = store.agg_count("domain", dom, 0, FOUR_HOURS)
    ranked = sorted(counts.items(), key=lambda kv: -kv[1])
    top = ranked[0][1]
    a = ranked[0][0]
    b = next(
        (d for d, c in ranked if c <= top * 0.15 and c > max(top * 0.02, 50)),
        ranked[len(ranked) // 4][0],
    )
    c = next((d for d, cc in reversed(ranked) if cc >= 20), ranked[-1][0])
    return [
        {"name": "A_bindex", "scheme": "batched_index", "tree": Eq("domain", a)},
        {"name": "B_bindex", "scheme": "batched_index", "tree": Eq("domain", b)},
        {"name": "C_bindex", "scheme": "batched_index", "tree": Eq("domain", c)},
        {"name": "B_bscan", "scheme": "batched_scan", "tree": Eq("domain", b)},
    ]


def _oracle_counts(store, mix) -> Dict[str, int]:
    return {
        q["name"]: sum(
            b.n
            for b in QueryProcessor(store).run_scheme(
                q["scheme"], 0, FOUR_HOURS, q["tree"]
            )
        )
        for q in mix
    }


def _session_pass(svc, mix, out: Dict, name: str):
    """One client: stream the whole query mix through one session,
    recording per-query TTFR, total latency, counts, and the committed
    QueryProfile (the TTFR anatomy the breakdown columns report)."""
    s = svc.session(name)
    ttfr, totals, counts, waits, profiles = [], [], {}, [], []
    for q in mix:
        sq = s.submit(q["scheme"], 0, FOUR_HOURS, q["tree"])
        n = sq.count()
        counts[q["name"]] = n
        ttfr.append(sq.first_result_s)
        totals.append(sq.total_s)
        waits.append(sq.queue_wait_s)
        profiles.append(sq.profile.as_dict())
    s.close()
    out["ttfr"] = ttfr
    out["totals"] = totals
    out["counts"] = counts
    out["queue_wait_s"] = float(sum(waits))
    out["profiles"] = profiles


def _round(svc, mix, n_sessions: int, ingest_feed=None) -> Dict:
    """One sweep point: n_sessions client threads streaming the mix
    concurrently; optionally a writer thread ingesting throughout."""
    outs = [dict() for _ in range(n_sessions)]
    threads = [
        threading.Thread(
            target=_session_pass, args=(svc, mix, outs[i], f"s{i}")
        )
        for i in range(n_sessions)
    ]
    stop_feed = threading.Event()
    feeder = None
    if ingest_feed is not None:
        feeder = threading.Thread(target=ingest_feed, args=(stop_feed,))
    t0 = time.perf_counter()
    if feeder is not None:
        feeder.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop_feed.set()
    if feeder is not None:
        feeder.join()
    dt = time.perf_counter() - t0
    med = [float(np.median(o["ttfr"])) for o in outs]
    all_ttfr = [t for o in outs for t in o["ttfr"]]
    return {
        "sessions": n_sessions,
        "ingest": ingest_feed is not None,
        "wall_s": dt,
        "queries": n_sessions * len(mix),
        "ttfr_median_per_session": med,
        "ttfr_median_max": max(med),
        "ttfr_mean": float(np.mean(all_ttfr)),
        "ttfr_max": float(np.max(all_ttfr)),
        # Distribution tails across ALL queries of the round: p99 is the
        # incremental-compaction headline — before PR 6 a live-ingest
        # round's tail was one whole major compaction parked in front of
        # some session's first result.
        "ttfr_p50": float(np.percentile(all_ttfr, 50)),
        "ttfr_p99": float(np.percentile(all_ttfr, 99)),
        "queue_wait_s": float(sum(o["queue_wait_s"] for o in outs)),
        "counts": [o["counts"] for o in outs],
        # TTFR anatomy (QueryProfile): mean seconds per first-result
        # stage across every query of the round, plus the worst
        # stage-sum-vs-measured-TTFR gap (the tiling check validate()
        # asserts at 4 sessions).
        **_breakdown([pr for o in outs for pr in o["profiles"]]),
    }


_STAGES = ("admission", "plan", "density_fence", "device_step", "epilogue", "deliver")


def _breakdown(profiles: List[Dict]) -> Dict:
    gaps_rel, gaps_us = [0.0], [0.0]
    for pr in profiles:
        if pr["ttfr_s"] != pr["ttfr_s"] or pr["ttfr_s"] <= 0:  # NaN/never-first
            continue
        gap = abs(sum(pr[f"{st}_s"] for st in _STAGES) - pr["ttfr_s"])
        gaps_rel.append(gap / pr["ttfr_s"])
        gaps_us.append(gap * 1e6)
    return {
        "ttfr_breakdown_s": {
            st: float(np.mean([pr[f"{st}_s"] for pr in profiles])) for st in _STAGES
        },
        "breakdown_gap_max_rel": float(max(gaps_rel)),
        "breakdown_gap_max_us": float(max(gaps_us)),
    }


def run(quick: bool = False, n_rows: int = None) -> Dict:
    n_rows = n_rows or (15_000 if quick else 40_000)
    store, plane, src, (ts, cols, used) = _build(n_rows)
    mix = _paper_mix(store, src)
    oracle = _oracle_counts(store, mix)
    res: Dict = {"n_rows": n_rows, "mix": [q["name"] for q in mix]}
    with QueryService(store, plane, compaction_interval=0.01) as svc:

        def settle():
            # Round boundary: fold any leftover debt NOW (blocking until
            # any in-progress background fold finishes too), so a
            # multi-second major never straddles into the next round's
            # first TTFR. Mid-round folds still happen and are reported —
            # that stall is the paper's Fig 4 physics — but each round's
            # numbers are self-contained.
            svc.wait_idle()
            plane.compact()

        # Warm every compiled path once (XLA compiles are not the
        # scheduling cost under study), then measure the solo baseline —
        # two passes, median of both, since solo TTFR is the fairness
        # yardstick and a 4-sample median alone is noisy.
        _session_pass(svc, mix, {}, "warmup")
        settle()
        # Drop warmup turns from the scheduler log: their queue waits
        # absorb one-time query-path compiles, which the starvation
        # statistic (max first-turn wait) must not count.
        svc.scheduler.turn_log.clear()
        svc.compactor.max_increment_s = 0.0
        solo = _round(svc, mix, 1)
        settle()
        solo_b = _round(svc, mix, 1)
        res["solo_ttfr_median"] = float(
            np.median(
                solo["ttfr_median_per_session"] + solo_b["ttfr_median_per_session"]
            )
        )
        rounds = [solo]
        for n_s in SESSIONS[1:]:
            settle()
            rounds.append(_round(svc, mix, n_s))

        # With concurrent ingest: a writer streams reserve rows in small
        # chunks while the sessions query. Each query pins a publish
        # snapshot, so counts land between the before/after oracles.
        feed_pos = [used]

        def make_feed(chunk=256):
            # Paced writer: a saturating feeder would hold the plane lock
            # near-continuously and the benchmark would measure lock
            # starvation, not scheduling (the paper's ingest clients are
            # rate-limited by parsing; ~25ms between flushes plays that
            # role here).
            def feed(stop: threading.Event):
                w = DistBatchWriter(store, plane, batch_rows=chunk)
                while not stop.is_set() and feed_pos[0] + chunk <= len(ts):
                    sl = slice(feed_pos[0], feed_pos[0] + chunk)
                    w.add(ts[sl], {k: v[sl] for k, v in cols.items()})
                    feed_pos[0] += chunk
                    time.sleep(0.025)
                w.close()

            return feed

        oracle_before = oracle
        ingest_rounds = []
        for n_s in SESSIONS:
            settle()
            before = feed_pos[0]
            r = _round(svc, mix, n_s, ingest_feed=make_feed())
            # Sync the host oracle to everything acknowledged so far.
            sl = slice(before, feed_pos[0])
            if feed_pos[0] > before:
                store.ingest(ts[sl], {k: v[sl] for k, v in cols.items()})
                store.flush_all()
            r["oracle_before"] = oracle_before
            r["oracle_after"] = _oracle_counts(store, mix)
            oracle_before = r["oracle_after"]
            ingest_rounds.append(r)
        res["rounds"] = rounds
        res["ingest_rounds"] = ingest_rounds
        res["oracle"] = oracle

        # Sweep epilogue: the sessions are idle now; the background
        # compactor must get the device and fold the ingest leftovers.
        svc.wait_idle()
        deadline = time.time() + 120
        while plane.has_unfolded() and time.time() < deadline:
            time.sleep(0.02)
        res["compactor_folds"] = svc.compactor.folds
        res["compactor_skipped_busy"] = svc.compactor.skipped_busy
        # Incremental-compaction instrumentation: how many bounded
        # increments the drains decomposed into, the longest single
        # device-lock hold (the stall bound), and the worst queue wait
        # any session's FIRST-result turn observed — the starvation
        # guard the CI smoke asserts against the increment bound.
        res["compactor_increments"] = svc.compactor.increments
        res["compactor_max_increment_s"] = svc.compactor.max_increment_s
        res["compactor_preempted"] = svc.compactor.preempted
        res["max_first_turn_wait_s"] = svc.scheduler.max_first_turn_wait()
        # Device-lock occupancy over the whole sweep: which owner class
        # (session_turn / density_read / fold_increment) held the TTFR-
        # governing serialization point, and for how long (repro.obs).
        res["device_lock_occupancy"] = svc._device_lock.snapshot()
    tel = plane.telemetry()
    res["fold_events"] = tel["fold_events"]
    res["sessions_telemetry"] = tel["sessions"]
    res["rows_ingested_live"] = feed_pos[0] - used
    return res


def emit_csv(res: Dict) -> List[str]:
    lines = []
    for r in res["rounds"] + res["ingest_rounds"]:
        tag = f"table1_concurrency_s{r['sessions']}" + ("_ingest" if r["ingest"] else "")
        lines.append(
            f"{tag},{r['ttfr_median_max'] * 1e6:.0f},"
            f"ttfr_mean_us={r['ttfr_mean'] * 1e6:.0f};"
            f"ttfr_p50_us={r['ttfr_p50'] * 1e6:.0f};"
            f"ttfr_p99_us={r['ttfr_p99'] * 1e6:.0f};"
            f"ttfr_max_us={r['ttfr_max'] * 1e6:.0f};"
            f"queries={r['queries']};wall_s={r['wall_s']:.2f};"
            f"queue_wait_s={r['queue_wait_s']:.2f};"
            # TTFR anatomy columns (mean per stage, QueryProfile):
            f"admission_us={r['ttfr_breakdown_s']['admission'] * 1e6:.0f};"
            f"plan_us={r['ttfr_breakdown_s']['plan'] * 1e6:.0f};"
            f"fence_us={r['ttfr_breakdown_s']['density_fence'] * 1e6:.0f};"
            f"device_us={r['ttfr_breakdown_s']['device_step'] * 1e6:.0f};"
            f"epilogue_us={r['ttfr_breakdown_s']['epilogue'] * 1e6:.0f};"
            f"deliver_us={r['ttfr_breakdown_s']['deliver'] * 1e6:.0f}"
        )
    fe = ";".join(f"{k}={v}" for k, v in sorted(res["fold_events"].items()))
    lines.append(
        f"table1_concurrency_folds,{res['compactor_folds']},{fe or 'none'};"
        f"increments={res['compactor_increments']};"
        f"max_increment_ms={res['compactor_max_increment_s'] * 1e3:.1f};"
        f"max_first_turn_wait_ms={res['max_first_turn_wait_s'] * 1e3:.1f}"
    )
    return lines


def emit_json(res: Dict) -> Dict:
    """Canonical machine-readable artifact (BENCH_query_concurrency.json,
    written by benchmarks/run.py and checked in): rest + live-ingest TTFR
    p50/p99 per session count plus the incremental-compaction stall
    instrumentation — the perf trajectory future re-anchors track."""
    def row(r):
        return {
            "sessions": r["sessions"],
            "ingest": r["ingest"],
            "ttfr_p50_us": round(r["ttfr_p50"] * 1e6, 1),
            "ttfr_p99_us": round(r["ttfr_p99"] * 1e6, 1),
            "ttfr_median_max_us": round(r["ttfr_median_max"] * 1e6, 1),
            "ttfr_mean_us": round(r["ttfr_mean"] * 1e6, 1),
            "ttfr_max_us": round(r["ttfr_max"] * 1e6, 1),
            "queue_wait_s": round(r["queue_wait_s"], 4),
            "wall_s": round(r["wall_s"], 3),
            "queries": r["queries"],
            # TTFR anatomy: mean microseconds per first-result stage
            # (QueryProfile; the six stages tile each query's TTFR).
            "ttfr_breakdown_us": {
                st: round(v * 1e6, 1)
                for st, v in sorted(r["ttfr_breakdown_s"].items())
            },
            "breakdown_gap_max_rel": round(r["breakdown_gap_max_rel"], 4),
            "breakdown_gap_max_us": round(r["breakdown_gap_max_us"], 1),
        }

    return {
        # v2: adds per-stage TTFR breakdown columns per round.
        "schema_version": 2,
        "benchmark": "query_concurrency",
        "n_rows": res["n_rows"],
        "mix": res["mix"],
        "rest": [row(r) for r in res["rounds"]],
        "live_ingest": [row(r) for r in res["ingest_rounds"]],
        "rows_ingested_live": res["rows_ingested_live"],
        "fold_events": dict(res["fold_events"]),
        "compactor": {
            "folds": res["compactor_folds"],
            "increments": res["compactor_increments"],
            "max_increment_ms": round(res["compactor_max_increment_s"] * 1e3, 2),
            "preempted": res["compactor_preempted"],
            "skipped_busy": res["compactor_skipped_busy"],
        },
        "max_first_turn_wait_ms": round(res["max_first_turn_wait_s"] * 1e3, 2),
        "device_lock_occupancy": {
            "held_ms": round(res["device_lock_occupancy"]["total_held_s"] * 1e3, 2),
            "by_owner_ms": {
                k: round(v * 1e3, 2)
                for k, v in sorted(res["device_lock_occupancy"]["by_owner_s"].items())
            },
        },
    }


def validate(res: Dict) -> List[str]:
    fails = []
    oracle = res["oracle"]
    # Exactness: every session of every at-rest round matches the oracle.
    for r in res["rounds"]:
        for i, counts in enumerate(r["counts"]):
            for name, got in counts.items():
                if got != oracle[name]:
                    fails.append(
                        f"s{r['sessions']} session {i} {name}: {got} != oracle {oracle[name]}"
                    )
    # Ingest rounds: pinned snapshots put every count between the
    # before/after oracles (monotone ingest, append-only workload).
    for r in res["ingest_rounds"]:
        for i, counts in enumerate(r["counts"]):
            for name, got in counts.items():
                lo, hi = r["oracle_before"][name], r["oracle_after"][name]
                if not (lo <= got <= hi):
                    fails.append(
                        f"ingest s{r['sessions']} session {i} {name}: "
                        f"{got} outside [{lo}, {hi}]"
                    )
    # No starvation: at 4 concurrent sessions every session's median TTFR
    # within 3x the solo value.
    solo = res["solo_ttfr_median"]
    four = next(r for r in res["rounds"] if r["sessions"] == 4)
    for i, m in enumerate(four["ttfr_median_per_session"]):
        if m > 3.0 * solo:
            fails.append(
                f"starvation at 4 sessions: session {i} ttfr {m * 1e3:.1f}ms "
                f"> 3x solo {solo * 1e3:.1f}ms"
            )
    # Bounded-stall compaction: the live-ingest p99 TTFR at 4 sessions
    # stays within 2x the at-rest p99 — before incremental folds the gap
    # was a whole major compaction (seconds). A small absolute floor
    # keeps the ratio meaningful when both tails are sub-millisecond.
    rest4 = next(r for r in res["rounds"] if r["sessions"] == 4)
    live4 = next(r for r in res["ingest_rounds"] if r["sessions"] == 4)
    bound = max(2.0 * rest4["ttfr_p99"], rest4["ttfr_p99"] + 0.025)
    if live4["ttfr_p99"] > bound:
        fails.append(
            f"live-ingest p99 TTFR {live4['ttfr_p99'] * 1e3:.1f}ms exceeds "
            f"2x at-rest p99 {rest4['ttfr_p99'] * 1e3:.1f}ms at 4 sessions"
        )
    # TTFR anatomy tiles the measurement: at 4 concurrent sessions every
    # query's six-stage sum lands within 5% of its measured TTFR (a 75us
    # absolute floor keeps clock-read slack from failing sub-ms queries).
    for r in (rest4, live4):
        if r["breakdown_gap_max_rel"] > 0.05 and r["breakdown_gap_max_us"] > 75.0:
            tag = "live-ingest" if r["ingest"] else "at-rest"
            fails.append(
                f"TTFR breakdown does not tile at 4 sessions ({tag}): worst "
                f"gap {r['breakdown_gap_max_rel']:.2%} "
                f"({r['breakdown_gap_max_us']:.0f}us)"
            )
    # Background compaction happened, and nothing folded on the query path.
    if res["compactor_folds"] < 1:
        fails.append("background compactor never folded during the sweep")
    if res["compactor_increments"] < 1:
        fails.append("compactor never ran an incremental compact_step")
    bad_sources = set(res["fold_events"]) - {"ingest", "background", "explicit"}
    if bad_sources:
        fails.append(f"fold attributed to unexpected source(s): {bad_sources}")
    if res["fold_events"].get("background", 0) < 1:
        fails.append("no fold attributed to the background compactor")
    if res["rows_ingested_live"] <= 0:
        fails.append("concurrent-ingest rounds never ingested a row")
    return fails


if __name__ == "__main__":
    r = run(quick=True)
    print("\n".join(emit_csv(r)))
    f = validate(r)
    print(f"# {len(f)} validation failure(s)")
    for line in f:
        print("#", line)
