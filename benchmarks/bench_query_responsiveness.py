"""Paper Table I + Fig 5: query responsiveness — latency to the 1st /
100th / 1000th result row for queries A/B/C under the four execution
schemes (Scan, Batched Scan, Index, Batched Index).

Validation targets (qualitative, per the paper):
  * Batched Index delivers the fastest first result for ALL three queries.
  * Batched schemes beat their unbatched counterparts on first-result
    latency by an order of magnitude on large ranges.
  * Plain Index beats plain Scan at high selectivity (Query C) but not at
    low selectivity (Query A).
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import Eq, QueryProcessor, QueryStats

from .common import BenchStore, paper_queries

SCHEMES = ["scan", "batched_scan", "index", "batched_index"]
MILESTONES = [1, 100, 1000]


def run_one(bs: BenchStore, scheme: str, domain: str) -> Dict:
    qp = QueryProcessor(bs.store)
    stats = QueryStats()
    tree = Eq("domain", domain)
    t0 = time.perf_counter()
    latency = {}
    rows = 0
    for blk in qp.run_scheme(scheme, bs.t_start, bs.t_stop, tree, stats=stats):
        now = time.perf_counter() - t0
        for m in MILESTONES:
            if rows < m <= rows + blk.n and m not in latency:
                latency[m] = now
        rows += blk.n
    total = time.perf_counter() - t0
    return {
        "scheme": scheme,
        "rows": rows,
        "total_s": total,
        "latency": latency,
        "batches": stats.batches,
    }


def run(bs: BenchStore) -> List[Dict]:
    queries = paper_queries(bs)
    out = []
    for qname, domain in queries.items():
        for scheme in SCHEMES:
            run_one(bs, scheme, domain)  # warm-up: jit caches (warm JVM analogue)
            r = run_one(bs, scheme, domain)
            r["query"] = qname
            r["domain"] = domain
            out.append(r)
    return out


def emit_csv(results: List[Dict]) -> List[str]:
    lines = []
    for r in results:
        first = r["latency"].get(1, float("nan"))
        lines.append(
            f"table1_responsiveness_{r['query']}_{r['scheme']},"
            f"{first * 1e6:.0f},rows={r['rows']};t100={r['latency'].get(100, float('nan')):.4f}"
            f";t1000={r['latency'].get(1000, float('nan')):.4f};total={r['total_s']:.3f}"
        )
    return lines


def validate(results: List[Dict]) -> List[str]:
    """The paper's qualitative claims as assertions; returns failures."""
    fails = []
    by = {(r["query"], r["scheme"]): r for r in results}
    for q in ["A", "B", "C"]:
        first = {s: by[(q, s)]["latency"].get(1, float("inf")) for s in SCHEMES}
        if min(first, key=first.get) != "batched_index":
            # Allow batched_scan ~ batched_index ties (paper Query A shows
            # "roughly equivalent performance").
            if first["batched_index"] > 1.25 * first["batched_scan"] and first[
                "batched_index"
            ] > first["index"]:
                fails.append(f"Q{q}: batched_index first-result not fastest: {first}")
        # The paper's batching-beats-scan claim lives in the regime where a
        # full scan takes many seconds (their Table I: 6-30 s). Assert it
        # only when the full scan is slow enough for batching to matter.
        if by[(q, "scan")]["latency"].get(1, 0.0) > 0.2 and first["batched_scan"] >= first["scan"]:
            fails.append(f"Q{q}: batching did not improve scan: {first}")
    # Index helps C (selective), not A (popular) — total runtime check.
    # Assert only when the scan is slow enough for the index to matter
    # (at millisecond scale both are overhead-dominated noise).
    if by[("C", "scan")]["total_s"] > 0.05 and (
        by[("C", "index")]["total_s"] >= by[("C", "scan")]["total_s"]
    ):
        fails.append("QC: index total runtime not better than scan")
    return fails

def emit_json(results: List[Dict]) -> Dict:
    """Canonical artifact (BENCH_query_responsiveness.json via
    benchmarks/run.py): Table I / Fig 5 milestone latencies per
    query x scheme."""
    return {
        "schema_version": 1,
        "benchmark": "query_responsiveness",
        "results": [
            {
                "query": r["query"],
                "domain": r["domain"],
                "scheme": r["scheme"],
                "rows": r["rows"],
                "batches": r["batches"],
                "total_ms": round(r["total_s"] * 1e3, 3),
                "latency_ms": {
                    str(m): round(v * 1e3, 3) for m, v in sorted(r["latency"].items())
                },
            }
            for r in results
        ],
    }
