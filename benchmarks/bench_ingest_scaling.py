"""Paper Fig 3 (ingest scaling + saturation) and Fig 4 (backpressure
regimes).

Three layers, all reported:

1. MEASURED (host): real multi-threaded ingest on the real store —
   per-client MB/s (the paper's 1.1 MB/s-per-client figure, our CPU's
   equivalent), tablet service rate, and a small W x S sweep. One CPU
   core caps the *absolute* numbers; the per-op costs are real.

2. MEASURED (device): the distributed ingest plane — W DistBatchWriters
   x T device-resident LSM tablets (core/dist_ingest.py), reporting
   rows/s, blocked-seconds and per-tablet compaction counts from the
   device telemetry counters. The host mesh serializes device work, so
   this measures the on-mesh write path's real costs, not parallelism.

3. CALIBRATED SIMULATION: the paper's 24-node cluster sweep (clients up
   to dozens, 1-8 tablet servers) does not fit on one core, so the
   Fig 3/4 curves are produced by a discrete-time queueing model whose
   two parameters (client production rate, tablet service rate) are the
   MEASURED values from layer 1. Reproduction targets: ingest rate linear
   in client count at low load; saturation level set by tablet-server
   count; rate variance (backpressure) rising sharply near saturation —
   the three regimes of Fig 4.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from repro.core import EventStore, web_proxy_schema
from repro.core.ingest import BatchWriter, IngestMetrics, rate_series
from repro.pipeline.sources import SyntheticWebProxySource, parse_web_proxy_lines


# --------------------------------------------------------------- measured
def measure_client_rate(n_rows: int = 40_000) -> Dict:
    """Un-throttled single client: parse + encode + batch-write."""
    src = SyntheticWebProxySource(seed=11)
    store = EventStore(web_proxy_schema(), n_shards=4, flush_rows=1 << 22)  # no compaction
    lines = src.gen_lines(n_rows, 0, 3600)
    nbytes = sum(len(l) for l in lines)
    m = IngestMetrics()
    w = BatchWriter(store, batch_rows=8192, metrics=m)
    t0 = time.perf_counter()
    ts, cols = parse_web_proxy_lines(lines)
    w.add(ts, cols, nbytes=nbytes)
    w.close()
    dt = time.perf_counter() - t0
    return {"rows_per_s": n_rows / dt, "mb_per_s": nbytes / dt / 1e6, "seconds": dt}


def measure_tablet_rate(n_rows: int = 200_000, flush_rows: int = 16384) -> Dict:
    """Server-side service rate: pre-encoded inserts incl. compactions."""
    store = EventStore(web_proxy_schema(), n_shards=1, flush_rows=flush_rows, max_runs=6)
    src = SyntheticWebProxySource(seed=12)
    lines = src.gen_lines(50_000, 0, 3600)
    ts, colvals = parse_web_proxy_lines(lines)
    cols = store.encode_events(ts, colvals)
    t0 = time.perf_counter()
    done = 0
    while done < n_rows:
        store.ingest_encoded(ts, cols)
        done += len(ts)
    dt = time.perf_counter() - t0
    bp = store.backpressure_stats()
    return {"rows_per_s": done / dt, "seconds": dt, **bp}


def real_sweep(workers_list=(1, 2, 4), n_shards: int = 4, rows_per_worker: int = 20_000) -> List[Dict]:
    """Real threaded ingest (GIL-bound ceiling — reported as such)."""
    out = []
    src = SyntheticWebProxySource(seed=13)
    for n_w in workers_list:
        store = EventStore(web_proxy_schema(), n_shards=n_shards, flush_rows=32768)
        lines_per = [src.gen_lines(rows_per_worker, 0, 3600) for _ in range(n_w)]
        metrics = [IngestMetrics() for _ in range(n_w)]

        def work(i):
            w = BatchWriter(store, batch_rows=8192, metrics=metrics[i])
            ls = lines_per[i]
            for j in range(0, len(ls), 4096):
                chunk = ls[j : j + 4096]
                ts, cols = parse_web_proxy_lines(chunk)
                w.add(ts, cols, nbytes=sum(len(l) for l in chunk))
            w.close()

        threads = [threading.Thread(target=work, args=(i,)) for i in range(n_w)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        total = n_w * rows_per_worker
        out.append(
            {
                "workers": n_w,
                "shards": n_shards,
                "rows_per_s": total / dt,
                "mb_per_s": sum(m.bytes for m in metrics) / dt / 1e6,
                "blocked_s": sum(m.blocked_seconds for m in metrics),
            }
        )
    return out


# --------------------------------------------------------- measured/device
def device_sweep(
    workers_list=(1, 2, 4),
    tablets_list=(1, 2, 4),
    rows_per_worker: int = 10_000,
    mem_rows: int = 1024,
    max_runs: int = 3,
) -> List[Dict]:
    """Measured W-clients x T-tablets ingest through the device plane.

    Writers interleave round-robin (deterministic stand-in for concurrent
    clients — device dispatch is serialized on one host core anyway, as in
    real_sweep). Small memtables + few run slots force the full LSM
    lifecycle: the blocked-seconds and compaction counts are the paper's
    backpressure signals measured on the mesh."""
    from repro.core.dist_ingest import DistBatchWriter, DistIngestPlane
    from repro.launch.mesh import make_dev_mesh

    out = []
    src = SyntheticWebProxySource(seed=21)
    for n_t in tablets_list:
        for n_w in workers_list:
            store = EventStore(web_proxy_schema(), n_shards=4)  # dictionary carrier
            mesh = make_dev_mesh(1, 1)
            plane = DistIngestPlane(
                mesh,
                store.schema.n_fields,
                capacity=rows_per_worker * n_w + mem_rows + 64,  # + warm-up rows
                tablets_per_device=n_t,
                mem_rows=mem_rows,
                max_runs=max_runs,
                append_rows=min(mem_rows, 512),
            )
            metrics = [IngestMetrics() for _ in range(n_w)]
            writers = [
                DistBatchWriter(store, plane, batch_rows=2048, metrics=metrics[i], writer_id=i)
                for i in range(n_w)
            ]
            parsed = []
            for i in range(n_w):
                lines = src.gen_lines(rows_per_worker, 0, 3600)
                ts, cols = parse_web_proxy_lines(lines)
                nbytes = sum(len(l) for l in lines)
                parsed.append((ts, cols, nbytes))
            # Warm the plane's jitted programs (append, and minor/major
            # via compact — publish no longer runs compactions) so the
            # timed window measures steady-state ingest, not XLA
            # compilation; the telemetry baseline is subtracted below.
            warm = np.arange(64, dtype=np.int32)
            plane.ingest(warm, np.zeros((64, store.schema.n_fields), np.int32),
                         warm % plane.n_tablets)
            plane.compact()
            plane.publish()
            base_tel = plane.telemetry()
            plane.blocked_seconds = 0.0
            plane._lock.reset()  # occupancy columns cover the timed window only
            chunk = 1024
            t0 = time.perf_counter()
            for off in range(0, rows_per_worker, chunk):
                for i, w in enumerate(writers):
                    ts, cols, nbytes = parsed[i]
                    sl = slice(off, off + chunk)
                    n_sl = len(ts[sl])
                    w.add(ts[sl], {k: v[sl] for k, v in cols.items()},
                          nbytes=nbytes * n_sl // rows_per_worker)
            for w in writers:
                w.close()
            dt = time.perf_counter() - t0
            tel = plane.telemetry()
            total = n_w * rows_per_worker
            occ = plane._lock.snapshot()
            out.append(
                {
                    "workers": n_w,
                    "tablets": n_t,
                    "rows": total,
                    "rows_per_s": total / dt,
                    "blocked_s": sum(m.blocked_seconds for m in metrics),
                    "minor_compactions": int((tel["minor"] - base_tel["minor"]).sum()),
                    "major_compactions": int((tel["major"] - base_tel["major"]).sum()),
                    "overflow": int(tel["overflow"].sum()),
                    "device_rows": int((tel["rows"] - base_tel["rows"]).sum()),
                    # Plane-lock occupancy over the timed window: how the
                    # serialization point's held time splits between raw
                    # appends and the fold work backpressure forced.
                    "lock_held_s": float(occ["total_held_s"]),
                    "lock_owner_s": {
                        k: round(float(v), 6) for k, v in occ["by_owner_s"].items()
                    },
                }
            )
    return out


# ------------------------------------------------ measured/group contention
def group_sweep(
    workers: int = 4,
    groups_list=(1, 4),
    rows_total: int = 24_000,
    skew=(8, 4, 2, 1),
    mem_rows: int = 512,
    max_runs: int = 2,
    capacity: int = 131_072,
) -> List[Dict]:
    """W REAL writer threads vs G tablet-group locks — the lock-split
    experiment the sharded plane exists for. Both configs run the same 4
    tablets with the same pre-encoded per-writer streams and writer i
    pinned to tablet i; only the group split changes.

    The load is SKEWED (`skew` weights rows per writer): writer 0 is the
    hot client, the regime the paper's backpressure section and the
    hot-tablet note in data_model.md describe. That skew is what makes
    the single lock expensive: flush/fold programs run over a whole
    GROUP's tablet slabs (dense capacity-padded arrays — cost scales
    with tablets per group, not fill), so with G=1 every blocking major
    the hot tablet trips folds all four tablets' slabs and every writer
    queues behind it on the one plane lock; with G=4 the hot group folds
    its own slab alone and the cold groups' writers never see it.
    Aggregate rows/s and the per-group lock occupancy books (held +
    acquire-wait, the `lock_group_*` artifact columns) quantify the
    split — validate() gates G=4 >= 1.5x G=1 at W=4."""
    from repro.core import keypack
    from repro.core.dist_ingest import DistIngestPlane
    from repro.launch.mesh import make_dev_mesh

    out = []
    src = SyntheticWebProxySource(seed=53)
    n_t = workers  # one tablet per writer: disjoint routing by construction
    store = EventStore(web_proxy_schema(), n_shards=4)  # dictionary carrier
    rows_w = [rows_total * w // sum(skew) for w in skew]
    per_writer = []
    for n_rows in rows_w:
        lines = src.gen_lines(n_rows, 0, 3600)
        ts, colvals = parse_web_proxy_lines(lines)
        cols = store.encode_events(np.asarray(ts, np.int64), colvals)
        rts = keypack.rev_ts(np.asarray(ts, np.int64)).astype(np.int32)
        per_writer.append((rts, cols))
    for n_g in groups_list:
        mesh = make_dev_mesh(1, 1)
        plane = DistIngestPlane(
            mesh,
            store.schema.n_fields,
            # Provisioned far beyond the bench rows on purpose: fold cost
            # is O(capacity) (dense padded slabs), so the slab size sets
            # how much a group-wide fold costs — the asymmetry under test.
            capacity=max(capacity, max(rows_w) + mem_rows + 64),
            tablets_per_device=n_t,
            mem_rows=mem_rows,
            max_runs=max_runs,
            append_rows=min(mem_rows, 512),
            n_groups=n_g,
        )
        # Warm append + minor/major/fold compiles outside the timed
        # window (groups share one step cache, so one warm covers all).
        warm = np.arange(n_t * 8, dtype=np.int32)
        plane.ingest(warm % np.int32(4096), np.zeros((n_t * 8, store.schema.n_fields), np.int32),
                     warm % np.int32(n_t))
        plane.warm_compaction()
        base_rows = int(plane.telemetry()["rows"].sum())
        plane.blocked_seconds = 0.0
        for g in plane.groups:
            g.lock.reset()  # occupancy columns cover the timed window only
        chunk = 4096

        def work(i):
            rts, cols = per_writer[i]
            tab = np.full(min(chunk, len(rts)), i, np.int32)
            for off in range(0, len(rts), chunk):
                sl = slice(off, off + chunk)
                n_sl = len(rts[sl])
                plane.ingest(rts[sl], cols[sl], tab[:n_sl], writer_id=i)

        threads = [threading.Thread(target=work, args=(i,)) for i in range(workers)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        tel = plane.telemetry()
        total = sum(rows_w)
        occ = [g.lock.snapshot() for g in plane.groups]
        out.append(
            {
                "workers": workers,
                "groups": n_g,
                "tablets": n_t,
                "rows": total,
                "device_rows": int(tel["rows"].sum()) - base_rows,
                "rows_per_s": total / dt,
                "blocked_s": float(plane.blocked_seconds),
                "major_compactions": int(tel["major"].sum()),
                "overflow": int(tel["overflow"].sum()),
                # Per-group lock books over the timed window: held time
                # (appends + folds that group ran) and acquire-wait (how
                # long writers queued on THIS lock — the contention the
                # split removes).
                "lock_group_held_s": {
                    f"g{g.gid}": round(float(s["total_held_s"]), 6)
                    for g, s in zip(plane.groups, occ)
                },
                "lock_group_wait_s": {
                    f"g{g.gid}": round(float(s["total_wait_s"]), 6)
                    for g, s in zip(plane.groups, occ)
                },
            }
        )
    return out


# ------------------------------------------------- measured/publish latency
def publish_latency_sweep(
    base_rows_list=(6_000, 60_000),
    delta_rows: int = 512,
    n_cycles: int = 5,
    mem_rows: int = 1024,
    max_runs: int = 4,
) -> List[Dict]:
    """publish() cost vs base fill — the headline fix of the run-aware
    read path. publish used to fold every run slab into the base (a
    device merge over the full tablet capacity) before queries could see
    fresh rows, so freshness cost grew with DATABASE size. Now reads
    search base + runs + sealed memtable and publish is a memtable seal
    (O(mem_rows)) plus a metadata flip: its latency must stay flat as the
    base fill grows 10x, and it must never trip a compaction.

    Per base size: bulk-ingest base_rows and fold them into the base via
    compact() (the batched background fold point), then run timed
    query-while-ingest cycles — ingest a small delta, publish, query —
    recording publish and query latency and asserting every delta row is
    visible. Ingest may trip its own minors as the deltas accumulate
    across cycles (normal LSM behavior, excluded by the per-publish
    telemetry deltas); what must stay zero is compaction attributable to
    PUBLISH itself — the measured publish cost is the pure freshness
    flip."""
    import jax

    from repro.core import EventStore, web_proxy_schema
    from repro.core.dist_ingest import DistBatchWriter, DistIngestPlane
    from repro.core.dist_query import DistQueryProcessor
    from repro.launch.mesh import make_dev_mesh

    out = []
    src = SyntheticWebProxySource(seed=31)
    for base_rows in base_rows_list:
        store = EventStore(web_proxy_schema(), n_shards=4)  # dictionary carrier
        mesh = make_dev_mesh(1, 1)
        plane = DistIngestPlane.for_store(
            store,
            mesh,
            capacity=int(base_rows * 0.75) + n_cycles * delta_rows + mem_rows,
            tablets_per_device=2,
            mem_rows=mem_rows,
            max_runs=max_runs,
            append_rows=min(mem_rows, 512),
        )
        w = DistBatchWriter(store, plane, batch_rows=4096, writer_id=0)
        lines = src.gen_lines(base_rows + n_cycles * delta_rows, 0, 3600)
        ts, cols = parse_web_proxy_lines(lines)
        w.add(ts[:base_rows], {k: v[:base_rows] for k, v in cols.items()})
        w.close()
        plane.compact()  # fold the bulk load: base fill == base_rows
        dq = DistQueryProcessor(store, plane=plane)
        dq.scan_range(None, 0, 7200)  # warm seal + scan compiles
        base_fill = int(plane.telemetry()["base_n"].sum())
        pub_s, query_s = [], []
        pub_minors = pub_majors = 0
        visible = base_rows
        for c in range(n_cycles):
            sl = slice(base_rows + c * delta_rows, base_rows + (c + 1) * delta_rows)
            wc = DistBatchWriter(store, plane, batch_rows=delta_rows, writer_id=1)
            wc.add(ts[sl], {k: v[sl] for k, v in cols.items()})
            wc.close()
            visible += delta_rows
            tel0 = plane.telemetry()
            t0 = time.perf_counter()
            ds = plane.publish()
            jax.block_until_ready(ds.mem_rev_ts)
            pub_s.append(time.perf_counter() - t0)
            # Compactions attributable to publish ITSELF (ingest may trip
            # its own minors between cycles) — MUST stay 0: the whole
            # point is that publish never folds.
            tel1 = plane.telemetry()
            pub_minors += int((tel1["minor"] - tel0["minor"]).sum())
            pub_majors += int((tel1["major"] - tel0["major"]).sum())
            t0 = time.perf_counter()
            count, _, _ = dq.scan_range(None, 0, 7200)
            query_s.append(time.perf_counter() - t0)
            assert count == visible, (count, visible)
        out.append(
            {
                "base_rows": base_fill,
                "delta_rows": delta_rows,
                "publish_us": float(np.median(pub_s) * 1e6),
                "query_us": float(np.median(query_s) * 1e6),
                "rows_visible": visible,
                "publish_majors": pub_majors,
                "publish_minors": pub_minors,
                "overflow": int(plane.telemetry()["overflow"].sum()),
            }
        )
    return out


# --------------------------------------------------- measured/seal latency
def seal_latency_probe(mem_rows: int = 65536, reps: int = 5) -> Dict:
    """Fill-bounded publish seal: the seal program sorts only the LIVE
    memtable fill (pow2-bucketed), not the slab capacity. A near-empty
    memtable must therefore publish measurably faster than a full one —
    this probe measures both on the SAME plane with a deliberately large
    memtable slab (65536 rows: big enough that the sort, not dispatch
    overhead, dominates), and reports the seal bucket actually used."""
    import jax

    from repro.core.dist_ingest import DistBatchWriter, DistIngestPlane
    from repro.launch.mesh import make_dev_mesh

    src = SyntheticWebProxySource(seed=47)
    store = EventStore(web_proxy_schema(), n_shards=2)  # dictionary carrier
    plane = DistIngestPlane.for_store(
        store,
        make_dev_mesh(1, 1),
        capacity=mem_rows * 2,
        tablets_per_device=1,
        mem_rows=mem_rows,
        max_runs=4,
        append_rows=8192,
    )
    n_fill = mem_rows - 64  # just under capacity: no flush, pure memtable
    lines = src.gen_lines(n_fill, 0, 3600)
    ts, cols = parse_web_proxy_lines(lines)
    w = DistBatchWriter(store, plane, batch_rows=8192)
    w.add(ts, cols)
    w.close()

    def timed_publishes() -> float:
        out = []
        for _ in range(reps):
            for g in plane.groups:
                with g.lock.hold("bookkeeping"):
                    g._dirty = True  # force a re-seal of the same state
                    # Defeat the generation-keyed seal reuse: with the mem
                    # gen unchanged, publish() would alias the cached
                    # sealed arrays and this probe would time only the
                    # snapshot flip, not the fill-bounded sort.
                    g._sealed_cache = None
            t0 = time.perf_counter()
            ds = plane.publish()
            jax.block_until_ready(ds.mem_rev_ts)
            out.append(time.perf_counter() - t0)
        return float(np.median(out))

    plane.publish()  # warm the full-fill seal compile outside the timing
    jax.block_until_ready(plane.state["ev_mem_k"])
    full_us = timed_publishes() * 1e6
    rows_full = plane.last_seal_rows
    plane.compact()  # drain: memtable empty, rows now in the base
    delta = src.gen_lines(96, 0, 3600)
    dts, dcols = parse_web_proxy_lines(delta)
    w2 = DistBatchWriter(store, plane, batch_rows=128)
    w2.add(dts, dcols)
    w2.close()
    plane.publish()  # warm the small-bucket seal compile
    jax.block_until_ready(plane.state["ev_base_k"])
    small_us = timed_publishes() * 1e6
    rows_small = plane.last_seal_rows
    return {
        "mem_rows": mem_rows,
        "publish_full_us": full_us,
        "publish_small_us": small_us,
        "sealed_rows_full": rows_full,
        "sealed_rows_small": rows_small,
        "speedup": full_us / max(small_us, 1e-9),
    }


# -------------------------------------------------------------- simulated
@dataclass
class SimResult:
    workers: int
    servers: int
    throughput: float  # rows/s steady state
    offered: float
    variance_ratio: float  # std/mean of instantaneous rate
    blocked_frac: float
    series: np.ndarray


def simulate(
    n_workers: int,
    n_servers: int,
    client_rate: float,
    server_rate: float,
    sim_s: float = 120.0,
    dt: float = 0.1,
    queue_cap_rows: float = 50_000.0,
    seed: int = 0,
) -> SimResult:
    """Discrete-time queueing model of the ingest path.

    Clients produce at client_rate (jittered) and round-robin-shard across
    servers (the paper's uniform random sharding). Each server drains its
    queue at server_rate, with periodic compaction stalls whose duration
    scales with data ingested since the last stall (the LSM merge cost).
    A full queue blocks the clients that route to it — backpressure."""
    rng = np.random.default_rng(seed)
    steps = int(sim_s / dt)
    q = np.zeros(n_servers)
    since_compact = np.zeros(n_servers)
    stall = np.zeros(n_servers)
    produced_series = np.zeros(steps)  # client-observed ingest rate (Fig 4 signal)
    blocked_steps = 0
    compact_every = server_rate * 4.0  # rows between stalls
    for i in range(steps):
        want = n_workers * client_rate * dt * rng.uniform(0.9, 1.1)
        # Backpressure: clients block while their shard's queue is full —
        # per-server admission since sharding is uniform.
        room = np.maximum(queue_cap_rows - q, 0.0)
        admit = np.minimum(want / n_servers, room)
        produced = admit.sum()
        if produced < want * 0.98:
            blocked_steps += 1
        q += admit
        service = server_rate * dt * rng.uniform(0.85, 1.15, n_servers)
        service = np.where(stall > 0, 0.0, service)  # stalled servers do not drain
        stall = np.maximum(stall - dt, 0.0)
        take = np.minimum(q, service)
        q -= take
        since_compact += take
        need = since_compact > compact_every * rng.uniform(0.8, 1.2, n_servers)
        # Compaction stall grows with merge debt AND queue depth (major
        # compactions merge everything that piled up).
        stall = np.where(need, (since_compact + q) / (server_rate * 5.0), stall)
        since_compact = np.where(need, 0.0, since_compact)
        produced_series[i] = produced / dt
    half = steps // 2
    steady = produced_series[half:]
    return SimResult(
        workers=n_workers,
        servers=n_servers,
        throughput=float(steady.mean()),
        offered=n_workers * client_rate,
        variance_ratio=float(steady.std() / max(steady.mean(), 1e-9)),
        blocked_frac=blocked_steps / steps,
        series=produced_series,
    )


def fig3_sweep(client_rate: float, server_rate: float) -> List[SimResult]:
    out = []
    for servers in (1, 2, 4, 8):
        for workers in (1, 2, 4, 8, 12, 16, 24, 32, 48, 64):
            out.append(simulate(workers, servers, client_rate, server_rate, seed=workers * 131 + servers))
    return out


def fig4_regimes(client_rate: float, server_rate: float, servers: int = 4) -> List[SimResult]:
    """Three regimes: well below capacity / near capacity / saturated."""
    cap = servers * server_rate
    out = []
    for frac in (0.3, 0.85, 1.15):
        workers = max(int(round(cap * frac / client_rate)), 1)
        out.append(simulate(workers, servers, client_rate, server_rate, sim_s=240.0, seed=7))
    return out


def run(quick: bool = False) -> Dict:
    client = measure_client_rate()
    tablet = measure_tablet_rate()
    sweep_real = real_sweep()
    sweep_device = device_sweep(
        workers_list=(1, 2) if quick else (1, 2, 4),
        tablets_list=(1, 2) if quick else (1, 2, 4),
        rows_per_worker=4_000 if quick else 10_000,
    )
    sweep_groups = group_sweep(
        rows_total=24_000 if quick else 48_000,
    )
    sweep_publish = publish_latency_sweep(
        base_rows_list=(4_000, 40_000) if quick else (6_000, 60_000),
    )
    seal = seal_latency_probe(mem_rows=16384 if quick else 65536)
    sims = fig3_sweep(client["rows_per_s"], tablet["rows_per_s"])
    regimes = fig4_regimes(client["rows_per_s"], tablet["rows_per_s"])
    return {
        "client": client,
        "tablet": tablet,
        "real_sweep": sweep_real,
        "device_sweep": sweep_device,
        "group_sweep": sweep_groups,
        "publish_sweep": sweep_publish,
        "seal_probe": seal,
        "fig3": sims,
        "fig4": regimes,
    }


def emit_csv(res: Dict) -> List[str]:
    lines = [
        f"fig3_client_rate,{1e6 / res['client']['rows_per_s']:.2f},mb_per_s={res['client']['mb_per_s']:.2f}",
        f"fig3_tablet_rate,{1e6 / res['tablet']['rows_per_s']:.2f},rows_per_s={res['tablet']['rows_per_s']:.0f}",
    ]
    for r in res["real_sweep"]:
        lines.append(
            f"fig3_real_w{r['workers']}_s{r['shards']},{1e6 * r['workers'] / max(r['rows_per_s'], 1):.2f},"
            f"rows_per_s={r['rows_per_s']:.0f};mb_per_s={r['mb_per_s']:.2f}"
        )
    for r in res.get("device_sweep", []):
        lines.append(
            f"fig3_device_w{r['workers']}_t{r['tablets']},"
            f"{1e6 * r['workers'] / max(r['rows_per_s'], 1):.2f},"
            f"rows_per_s={r['rows_per_s']:.0f};blocked_s={r['blocked_s']:.3f};"
            f"minor={r['minor_compactions']};major={r['major_compactions']}"
        )
    for r in res.get("group_sweep", []):
        lines.append(
            f"fig3_groups_w{r['workers']}_g{r['groups']},"
            f"{1e6 * r['workers'] / max(r['rows_per_s'], 1):.2f},"
            f"rows_per_s={r['rows_per_s']:.0f};blocked_s={r['blocked_s']:.3f};"
            f"wait_s={sum(r['lock_group_wait_s'].values()):.3f}"
        )
    for r in res.get("publish_sweep", []):
        lines.append(
            f"publish_latency_base{r['base_rows']},{r['publish_us']:.1f},"
            f"query_us={r['query_us']:.1f};rows={r['rows_visible']};"
            f"publish_majors={r['publish_majors']}"
        )
    if res.get("seal_probe"):
        s = res["seal_probe"]
        lines.append(
            f"publish_seal_full_m{s['mem_rows']},{s['publish_full_us']:.1f},"
            f"sealed_rows={s['sealed_rows_full']}"
        )
        lines.append(
            f"publish_seal_small_m{s['mem_rows']},{s['publish_small_us']:.1f},"
            f"sealed_rows={s['sealed_rows_small']};speedup={s['speedup']:.2f}"
        )
    for s in res["fig3"]:
        lines.append(
            f"fig3_sim_w{s.workers}_s{s.servers},{1e6 / max(s.throughput, 1):.3f},"
            f"thru={s.throughput:.0f};offered={s.offered:.0f};var={s.variance_ratio:.3f}"
        )
    for s, name in zip(res["fig4"], ("low", "near", "saturated")):
        lines.append(
            f"fig4_{name},{1e6 / max(s.throughput, 1):.3f},"
            f"var_ratio={s.variance_ratio:.3f};blocked={s.blocked_frac:.3f};workers={s.workers}"
        )
    return lines


def emit_json(res: Dict) -> Dict:
    """Canonical machine-readable artifact (BENCH_ingest_scaling.json,
    written via benchmarks/common.write_artifact and checked in): the
    measured device-sweep cells with their plane-lock occupancy
    breakdown, publish/seal latencies, and the calibrated-simulation
    summary rows — the ingest-path perf trajectory re-anchors track."""

    def sim_row(s) -> Dict:
        return {
            "workers": s.workers,
            "servers": s.servers,
            "throughput_rows_s": round(s.throughput, 1),
            "offered_rows_s": round(s.offered, 1),
            "variance_ratio": round(s.variance_ratio, 4),
            "blocked_frac": round(s.blocked_frac, 4),
        }

    def dev_row(r: Dict) -> Dict:
        return {
            "workers": r["workers"],
            "tablets": r["tablets"],
            "rows": r["rows"],
            "rows_per_s": round(r["rows_per_s"], 1),
            "blocked_ms": round(r["blocked_s"] * 1e3, 2),
            "minor_compactions": r["minor_compactions"],
            "major_compactions": r["major_compactions"],
            "lock_held_ms": round(r["lock_held_s"] * 1e3, 2),
            "lock_owner_ms": {
                k: round(v * 1e3, 2) for k, v in r["lock_owner_s"].items()
            },
        }

    def group_row(r: Dict) -> Dict:
        return {
            "workers": r["workers"],
            "groups": r["groups"],
            "tablets": r["tablets"],
            "rows": r["rows"],
            "rows_per_s": round(r["rows_per_s"], 1),
            "blocked_ms": round(r["blocked_s"] * 1e3, 2),
            "major_compactions": r["major_compactions"],
            "lock_group_held_ms": {
                k: round(v * 1e3, 2) for k, v in r["lock_group_held_s"].items()
            },
            "lock_group_wait_ms": {
                k: round(v * 1e3, 2) for k, v in r["lock_group_wait_s"].items()
            },
        }

    return {
        "benchmark": "ingest_scaling",
        "client_rows_per_s": round(res["client"]["rows_per_s"], 1),
        "tablet_rows_per_s": round(res["tablet"]["rows_per_s"], 1),
        "device_sweep": [dev_row(r) for r in res["device_sweep"]],
        "group_sweep": [group_row(r) for r in res.get("group_sweep", [])],
        "publish_sweep": [
            {
                "base_rows": r["base_rows"],
                "publish_us": round(r["publish_us"], 1),
                "query_us": round(r["query_us"], 1),
                "publish_majors": r["publish_majors"],
                "publish_minors": r["publish_minors"],
            }
            for r in res["publish_sweep"]
        ],
        "seal_probe": {
            k: (round(v, 2) if isinstance(v, float) else v)
            for k, v in res["seal_probe"].items()
        },
        "fig4_regimes": [sim_row(s) for s in res["fig4"]],
    }


def validate(res: Dict) -> List[str]:
    fails = []
    # Device plane: every produced row lands in a tablet (no overflow, no
    # loss), and the tiny-memtable configuration actually exercised the
    # blocking major-compaction path somewhere in the sweep.
    for r in res.get("device_sweep", []):
        if r["device_rows"] != r["rows"]:
            fails.append(
                f"device rows lost: w={r['workers']} t={r['tablets']} "
                f"{r['device_rows']} != {r['rows']}"
            )
        if r["overflow"]:
            fails.append(f"device tablet overflow: w={r['workers']} t={r['tablets']}")
    if res.get("device_sweep") and not any(
        r["major_compactions"] > 0 for r in res["device_sweep"]
    ):
        fails.append("device sweep never tripped a major compaction")
    # Sharded plane: the lock split must BUY throughput — 4 concurrent
    # writers over 4 group locks beat the same workload serialized behind
    # one lock by >= 1.5x, with no rows lost and the single-lock baseline
    # booking (strictly) more acquire-wait than all group locks combined.
    grp = {r["groups"]: r for r in res.get("group_sweep", [])}
    for r in grp.values():
        if r["device_rows"] != r["rows"]:
            fails.append(
                f"group sweep rows lost: g={r['groups']} "
                f"{r['device_rows']} != {r['rows']}"
            )
        if r["overflow"]:
            fails.append(f"group sweep tablet overflow: g={r['groups']}")
    if grp and (1 not in grp or 4 not in grp):
        fails.append(f"group sweep missing a config: have groups={sorted(grp)}")
    elif grp:
        speedup = grp[4]["rows_per_s"] / max(grp[1]["rows_per_s"], 1e-9)
        if speedup < 1.5:
            fails.append(
                f"lock split under 1.5x: G=4 {grp[4]['rows_per_s']:.0f} rows/s "
                f"vs G=1 {grp[1]['rows_per_s']:.0f} ({speedup:.2f}x)"
            )
        wait1 = sum(grp[1]["lock_group_wait_s"].values())
        wait4 = sum(grp[4]["lock_group_wait_s"].values())
        if wait4 >= wait1:
            fails.append(
                f"group locks waited as much as the single lock: "
                f"{wait4:.3f}s vs {wait1:.3f}s"
            )
    # Run-aware publish: NO compaction attributable to publish, every delta
    # row visible to the query-while-ingest cycle, and flat latency — the
    # largest base fill is 10x the smallest, so a publish that still paid
    # an O(capacity) re-merge would show an order-of-magnitude spread.
    pub = res.get("publish_sweep", [])
    for r in pub:
        if r["publish_majors"] or r["publish_minors"]:
            fails.append(
                f"publish folded at base={r['base_rows']}: "
                f"{r['publish_minors']} minors, {r['publish_majors']} majors"
            )
        if r["overflow"]:
            fails.append(f"publish sweep tablet overflow at base={r['base_rows']}")
    if pub:
        lo = min(r["publish_us"] for r in pub)
        hi = max(r["publish_us"] for r in pub)
        if hi / max(lo, 1e-9) > 5.0:
            fails.append(
                f"publish latency not flat vs base fill: {lo:.0f}us -> {hi:.0f}us"
            )
    # Fill-bounded seal: a near-empty memtable publishes FASTER than a
    # full one (the seal sorts the live fill, not the slab capacity), and
    # the seal bucket actually shrinks.
    seal = res.get("seal_probe")
    if seal:
        if seal["sealed_rows_small"] >= seal["sealed_rows_full"]:
            fails.append(
                f"seal bucket did not shrink on a near-empty memtable: "
                f"{seal['sealed_rows_small']} vs {seal['sealed_rows_full']}"
            )
        if seal["publish_small_us"] * 1.2 > seal["publish_full_us"]:
            fails.append(
                f"publish latency did not drop on a near-empty memtable: "
                f"full {seal['publish_full_us']:.0f}us vs "
                f"small {seal['publish_small_us']:.0f}us"
            )
    # Linear scaling at low load: sim throughput for (w, s=8) ~ w * client.
    c = res["client"]["rows_per_s"]
    for s in res["fig3"]:
        if s.servers == 8 and s.workers <= 4:
            if abs(s.throughput - s.offered) > 0.15 * s.offered:
                fails.append(f"not linear at low load: w={s.workers} thru={s.throughput:.0f} offered={s.offered:.0f}")
    # Saturation set by server count: max throughput ratio s=8 vs s=1 ~ 8x.
    max1 = max(s.throughput for s in res["fig3"] if s.servers == 1)
    max8 = max(s.throughput for s in res["fig3"] if s.servers == 8)
    if not 4.0 < max8 / max1 < 12.0:
        fails.append(f"saturation not set by server count: max8/max1={max8 / max1:.2f}")
    # Variance regimes (Fig 4): the paper's claim is low variance well
    # below capacity and HIGH variance at/near saturation ("dips" appear
    # near the limit, "high variation" at saturation). Near-vs-saturated
    # are both hot regimes and not strictly ordered — at deep saturation
    # the admission-limited rate can steady out slightly.
    v = [s.variance_ratio for s in res["fig4"]]
    # Low-load variance must sit clearly under the saturated regime and
    # below near-capacity. (Near-capacity alone is too jumpy a yardstick:
    # its worker count comes from integer rounding against the measured
    # rates, so its variance ratio can dip toward the 2x line run-to-run.)
    if not (v[0] < v[1] and v[0] < 0.5 * v[2]):
        fails.append(f"variance did not rise toward saturation: {v}")
    blocked = [s.blocked_frac for s in res["fig4"]]
    if not (blocked[0] < 0.05 and blocked[2] > 0.5):
        fails.append(f"backpressure blocking regimes wrong: {blocked}")
    return fails
