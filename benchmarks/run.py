"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV lines and a validation summary of
the paper's qualitative claims. Tables map to the paper as:

    fig3_*    Fig 3   ingest scaling vs clients x servers (+ saturation)
    fig4_*    Fig 4   backpressure regimes (rate variance)
    table1_*  Table I query responsiveness (time-to-first-result)
    table1_concurrency_*  (ours) first-result latency vs concurrent
              sessions over the serve plane, at rest and under live ingest
    table2_*  Table II query total runtime
    kernel_*  (ours)  store kernel throughput

Every benchmarks/bench_*.py module is wired through this harness — CSV
lines, validate() failures (where the module defines them), and a
checked-in BENCH_<name>.json artifact (common.write_artifact) per
module. None are manual-only.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller datasets (CI-sized)")
    ap.add_argument("--rows", type=int, default=None, help="bench store size")
    args = ap.parse_args()

    from . import (
        bench_ingest_scaling,
        bench_kernels,
        bench_query_concurrency,
        bench_query_responsiveness,
        bench_query_runtime,
    )
    from .common import build_bench_store, write_artifact

    lines = []
    failures = []

    print("# building bench store ...", file=sys.stderr, flush=True)
    n_rows = args.rows or (30_000 if args.quick else 120_000)
    bs = build_bench_store(n_rows=n_rows)

    print("# table I / fig 5: query responsiveness ...", file=sys.stderr, flush=True)
    r1 = bench_query_responsiveness.run(bs)
    lines += bench_query_responsiveness.emit_csv(r1)
    failures += [f"responsiveness: {f}" for f in bench_query_responsiveness.validate(r1)]
    print("# wrote", write_artifact("query_responsiveness",
                                    bench_query_responsiveness.emit_json(r1)),
          file=sys.stderr, flush=True)

    print("# table II: query runtime ...", file=sys.stderr, flush=True)
    r2 = bench_query_runtime.run(bs)
    lines += bench_query_runtime.emit_csv(r2)
    failures += [f"runtime: {f}" for f in bench_query_runtime.validate(r2)]
    # Canonical checked-in artifacts (benchmarks/BENCH_*.json, one shared
    # emitter in common.py): regenerated on every harness run so
    # re-anchors can track the perf trajectory (docs/benchmarks.md).
    print("# wrote", write_artifact("query_runtime", bench_query_runtime.emit_json(r2)),
          file=sys.stderr, flush=True)

    print("# fig 3/4: ingest scaling + backpressure ...", file=sys.stderr, flush=True)
    r3 = bench_ingest_scaling.run(quick=args.quick)
    lines += bench_ingest_scaling.emit_csv(r3)
    failures += [f"ingest: {f}" for f in bench_ingest_scaling.validate(r3)]
    print("# wrote", write_artifact("ingest_scaling", bench_ingest_scaling.emit_json(r3)),
          file=sys.stderr, flush=True)

    print("# serve plane: latency vs concurrent sessions ...", file=sys.stderr, flush=True)
    r5 = bench_query_concurrency.run(quick=args.quick)
    lines += bench_query_concurrency.emit_csv(r5)
    failures += [f"concurrency: {f}" for f in bench_query_concurrency.validate(r5)]
    print("# wrote", write_artifact("query_concurrency", bench_query_concurrency.emit_json(r5)),
          file=sys.stderr, flush=True)

    print("# kernels ...", file=sys.stderr, flush=True)
    r4 = bench_kernels.run()
    lines += bench_kernels.emit_csv(r4)
    print("# wrote", write_artifact("kernels", bench_kernels.emit_json(r4)),
          file=sys.stderr, flush=True)

    print("name,us_per_call,derived")
    for line in lines:
        print(line)

    print(f"\n# paper-claim validation: {len(failures)} failure(s)", file=sys.stderr)
    for f in failures:
        print(f"#   FAIL {f}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
