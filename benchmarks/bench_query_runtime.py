"""Paper Table II: total query runtime to completion for the four schemes,
plus the iterator stack's fused combine-scan scheme (scan-time aggregation),
plus the DISTRIBUTED variants of all four schemes (`dist_*` rows) — the
paper's Fig 6/7 comparison running on the device mesh.

Validation targets: batching overhead on total runtime is small (the paper
calls it 'negligible for interactive applications'); index total runtime
scales with selectivity (C << B << A); the combine-scan scheme ships MUCH
fewer bytes to the client than row-fetch for the same query — the whole
point of running the combiner server-side; distributed counts agree
exactly with the host schemes; and dist batched_index beats dist
filter-scan on latency-to-first-result for the selective query (the
candidate-gather index step touches max_rows candidate rows per batch
instead of evaluating the predicate over every tablet row)."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import AggregateSpec, Eq, QueryProcessor

from .common import BenchStore, paper_queries, time_stats, timed

SCHEMES = ["scan", "batched_scan", "index", "batched_index"]
# Pass 0 warms jit caches (first-trace XLA compiles); only later passes
# enter the reported stats, so percentile columns measure steady state.
WARMUP_PASSES = 1
MEASURED_PASSES = 2
DIST_SCHEMES = ["scan", "batched_scan", "index", "batched_index"]

# The aggregation the combine-scan scheme answers for each query: "count
# matching events per status per hour" — results are per-group partials,
# not rows.
AGG_SPEC = AggregateSpec(group_by=("status",), op="count", time_bucket_s=3600)


def run(bs: BenchStore) -> List[Dict]:
    queries = paper_queries(bs)
    out = []
    for qname, domain in queries.items():
        tree = Eq("domain", domain)
        for scheme in SCHEMES:
            times, last = [], None
            for _ in range(WARMUP_PASSES + MEASURED_PASSES):
                qp = QueryProcessor(bs.store)

                def drain():
                    rows = 0
                    nbytes = 0
                    for b in qp.run_scheme(scheme, bs.t_start, bs.t_stop, tree):
                        rows += b.n
                        nbytes += b.nbytes
                    return rows, nbytes

                dt, (rows, nbytes) = timed(drain)
                times.append(dt)
                last = (rows, nbytes)
            stats = time_stats(times, warmup=WARMUP_PASSES)
            out.append(
                {"query": qname, "domain": domain, "scheme": scheme,
                 "total_s": stats["median_s"], "time_stats": stats,
                 "rows": last[0], "client_bytes": last[1]}
            )
        # Fused combine-scan: same filter, but the server returns per-group
        # aggregates. 'rows' = events combined (comparable to row-fetch
        # rows); client_bytes = aggregate partial bytes actually shipped.
        times, last = [], None
        for _ in range(WARMUP_PASSES + MEASURED_PASSES):
            qp = QueryProcessor(bs.store)

            def drain_agg():
                matched = 0
                nbytes = 0
                for b in qp.run_scheme(
                    "combine_scan", bs.t_start, bs.t_stop, tree, aggregate=AGG_SPEC
                ):
                    matched += b.matched
                    nbytes += b.nbytes
                return matched, nbytes

            dt, (rows, nbytes) = timed(drain_agg)
            times.append(dt)
            last = (rows, nbytes)
        stats = time_stats(times, warmup=WARMUP_PASSES)
        out.append(
            {"query": qname, "domain": domain, "scheme": "combine_scan",
             "total_s": stats["median_s"], "time_stats": stats,
             "rows": last[0], "client_bytes": last[1]}
        )
    out += run_dist(bs)
    return out


def run_dist(bs: BenchStore, tablets_per_device: int = 2) -> List[Dict]:
    """The four schemes on the device mesh: one DistQueryProcessor (step
    caches persist across passes), re-sharded from the bench store through
    the ingest plane — so the index/aggregate tablets are the live
    device-maintained ones, exactly what production queries would see.
    Each row also records latency-to-first-result (`first_s`): the
    batched-index-vs-filter-scan gap there is the scheme's whole point."""
    from repro.core.dist_query import DistQueryProcessor, from_event_store
    from repro.launch.mesh import make_dev_mesh

    mesh = make_dev_mesh(1, 1)
    dist = from_event_store(bs.store, mesh, tablets_per_device=tablets_per_device)
    dq = DistQueryProcessor(bs.store, dist)
    queries = paper_queries(bs)
    out = []
    for qname, domain in queries.items():
        tree = Eq("domain", domain)
        for scheme in DIST_SCHEMES:
            times, best = [], None
            for _ in range(WARMUP_PASSES + MEASURED_PASSES):
                t0 = time.perf_counter()
                first = float("nan")
                rows = 0
                nbytes = 0
                for b in dq.run_scheme(scheme, bs.t_start, bs.t_stop, tree):
                    if b.n and rows == 0:
                        first = time.perf_counter() - t0
                    rows += b.n
                    nbytes += b.nbytes
                times.append(time.perf_counter() - t0)
                best = (first, rows, nbytes)
            stats = time_stats(times, warmup=WARMUP_PASSES)
            out.append(
                {"query": qname, "domain": domain, "scheme": f"dist_{scheme}",
                 "total_s": stats["median_s"], "time_stats": stats,
                 "first_s": best[0], "rows": best[1],
                 "client_bytes": best[2], "rows_per_tablet": dist.capacity,
                 "index_rows": dq.index_rows}
            )
    return out


def emit_csv(results: List[Dict]) -> List[str]:
    lines = []
    for r in results:
        derived = f"rows={r['rows']};client_bytes={r['client_bytes']}"
        if "first_s" in r:
            derived += f";first_us={r['first_s'] * 1e6:.0f}"
        lines.append(f"table2_runtime_{r['query']}_{r['scheme']},{r['total_s'] * 1e6:.0f},{derived}")
    return lines


def emit_json(results: List[Dict]) -> Dict:
    """Canonical machine-readable artifact (BENCH_query_runtime.json,
    written via benchmarks/common.write_artifact and checked in): Table
    II total runtimes per (query, scheme) with post-warmup median/p95 —
    compile passes are excluded by run()'s WARMUP_PASSES, so the
    percentile columns measure steady state."""

    def row(r: Dict) -> Dict:
        st = r.get("time_stats", {})
        d = {
            "query": r["query"],
            "scheme": r["scheme"],
            "total_us": round(r["total_s"] * 1e6, 1),
            "p95_us": round(st.get("p95_s", r["total_s"]) * 1e6, 1),
            "passes_measured": st.get("n", 1),
            "rows": r["rows"],
            "client_bytes": r["client_bytes"],
        }
        if "first_s" in r:
            d["first_us"] = round(r["first_s"] * 1e6, 1)
        return d

    return {
        "benchmark": "query_runtime",
        "warmup_passes": WARMUP_PASSES,
        "rows": [row(r) for r in results],
    }


def validate(results: List[Dict]) -> List[str]:
    fails = []
    by = {(r["query"], r["scheme"]): r for r in results}
    for q in ["A", "B", "C"]:
        scan, bscan = by[(q, "scan")]["total_s"], by[(q, "batched_scan")]["total_s"]
        if bscan > 2.5 * scan + 0.5:
            fails.append(f"Q{q}: batching overhead excessive: scan={scan:.2f} batched={bscan:.2f}")
    idx = {q: by[(q, "index")]["total_s"] for q in "ABC"}
    # Ordering with slack: sub-millisecond runtimes are noise-dominated.
    tol = 1e-3
    if not (idx["C"] <= idx["B"] * 1.5 + tol and idx["B"] <= idx["A"] * 1.5 + tol):
        fails.append(f"index runtime not ordered by selectivity: {idx}")
    # Iterator stack claim: scan-time aggregation must ship fewer bytes
    # than fetching the matching rows (same filter, same range).
    for q in ["A", "B", "C"]:
        row_bytes = by[(q, "batched_scan")]["client_bytes"]
        agg_bytes = by[(q, "combine_scan")]["client_bytes"]
        if by[(q, "combine_scan")]["rows"] and agg_bytes >= row_bytes:
            fails.append(
                f"Q{q}: combine_scan shipped {agg_bytes}B >= row-fetch {row_bytes}B"
            )
    # Distributed schemes: exact host agreement on matched-row counts.
    for q in ["A", "B", "C"]:
        host_rows = by[(q, "batched_scan")]["rows"]
        for s in DIST_SCHEMES:
            if by[(q, f"dist_{s}")]["rows"] != host_rows:
                fails.append(
                    f"Q{q}: dist_{s} rows {by[(q, f'dist_{s}')]['rows']} != host {host_rows}"
                )
    # The distributed index claim (paper Figs 6/7 on-mesh): for the
    # selective query, batched_index reaches its first result faster than
    # batched filter-scan. The index step's slab work (sort/expand over
    # max_rows candidates) is FIXED per batch while filter-scan work
    # scales with tablet rows, so the claim only holds — and is only
    # asserted — when tablets are much larger than the candidate slab
    # (the production regime; CI-sized quick stores skip it).
    c_row = by[("C", "dist_batched_index")]
    scan_first = by[("C", "dist_batched_scan")]["first_s"]
    idx_first = c_row["first_s"]
    big_enough = c_row["rows_per_tablet"] >= 8 * c_row["index_rows"]
    if big_enough and scan_first > 2e-3 and not (idx_first < scan_first):
        fails.append(
            f"QC: dist batched_index first-result {idx_first:.4f}s not faster "
            f"than dist filter-scan {scan_first:.4f}s"
        )
    return fails
