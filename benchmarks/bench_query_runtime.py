"""Paper Table II: total query runtime to completion for the four schemes.

Validation targets: batching overhead on total runtime is small (the paper
calls it 'negligible for interactive applications'); index total runtime
scales with selectivity (C << B << A)."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import Eq, QueryProcessor

from .common import BenchStore, paper_queries, timed

SCHEMES = ["scan", "batched_scan", "index", "batched_index"]


def run(bs: BenchStore) -> List[Dict]:
    queries = paper_queries(bs)
    out = []
    for qname, domain in queries.items():
        for scheme in SCHEMES:
            tree = Eq("domain", domain)
            best = None
            for _ in range(2):  # first pass warms jit caches
                qp = QueryProcessor(bs.store)
                dt, rows = timed(
                    lambda: sum(b.n for b in qp.run_scheme(scheme, bs.t_start, bs.t_stop, tree))
                )
                best = (dt, rows)
            out.append(
                {"query": qname, "domain": domain, "scheme": scheme,
                 "total_s": best[0], "rows": best[1]}
            )
    return out


def emit_csv(results: List[Dict]) -> List[str]:
    return [
        f"table2_runtime_{r['query']}_{r['scheme']},{r['total_s'] * 1e6:.0f},rows={r['rows']}"
        for r in results
    ]


def validate(results: List[Dict]) -> List[str]:
    fails = []
    by = {(r["query"], r["scheme"]): r for r in results}
    for q in ["A", "B", "C"]:
        scan, bscan = by[(q, "scan")]["total_s"], by[(q, "batched_scan")]["total_s"]
        if bscan > 2.5 * scan + 0.5:
            fails.append(f"Q{q}: batching overhead excessive: scan={scan:.2f} batched={bscan:.2f}")
    idx = {q: by[(q, "index")]["total_s"] for q in "ABC"}
    # Ordering with slack: sub-millisecond runtimes are noise-dominated.
    tol = 1e-3
    if not (idx["C"] <= idx["B"] * 1.5 + tol and idx["B"] <= idx["A"] * 1.5 + tol):
        fails.append(f"index runtime not ordered by selectivity: {idx}")
    return fails
