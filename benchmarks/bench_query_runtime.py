"""Paper Table II: total query runtime to completion for the four schemes,
plus the iterator stack's fused combine-scan scheme (scan-time aggregation).

Validation targets: batching overhead on total runtime is small (the paper
calls it 'negligible for interactive applications'); index total runtime
scales with selectivity (C << B << A); and the combine-scan scheme ships
MUCH fewer bytes to the client than row-fetch for the same query — the
whole point of running the combiner server-side."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import AggregateSpec, Eq, QueryProcessor

from .common import BenchStore, paper_queries, timed

SCHEMES = ["scan", "batched_scan", "index", "batched_index"]

# The aggregation the combine-scan scheme answers for each query: "count
# matching events per status per hour" — results are per-group partials,
# not rows.
AGG_SPEC = AggregateSpec(group_by=("status",), op="count", time_bucket_s=3600)


def run(bs: BenchStore) -> List[Dict]:
    queries = paper_queries(bs)
    out = []
    for qname, domain in queries.items():
        tree = Eq("domain", domain)
        for scheme in SCHEMES:
            best = None
            for _ in range(2):  # first pass warms jit caches
                qp = QueryProcessor(bs.store)

                def drain():
                    rows = 0
                    nbytes = 0
                    for b in qp.run_scheme(scheme, bs.t_start, bs.t_stop, tree):
                        rows += b.n
                        nbytes += b.nbytes
                    return rows, nbytes

                dt, (rows, nbytes) = timed(drain)
                best = (dt, rows, nbytes)
            out.append(
                {"query": qname, "domain": domain, "scheme": scheme,
                 "total_s": best[0], "rows": best[1], "client_bytes": best[2]}
            )
        # Fused combine-scan: same filter, but the server returns per-group
        # aggregates. 'rows' = events combined (comparable to row-fetch
        # rows); client_bytes = aggregate partial bytes actually shipped.
        best = None
        for _ in range(2):
            qp = QueryProcessor(bs.store)

            def drain_agg():
                matched = 0
                nbytes = 0
                for b in qp.run_scheme(
                    "combine_scan", bs.t_start, bs.t_stop, tree, aggregate=AGG_SPEC
                ):
                    matched += b.matched
                    nbytes += b.nbytes
                return matched, nbytes

            dt, (rows, nbytes) = timed(drain_agg)
            best = (dt, rows, nbytes)
        out.append(
            {"query": qname, "domain": domain, "scheme": "combine_scan",
             "total_s": best[0], "rows": best[1], "client_bytes": best[2]}
        )
    return out


def emit_csv(results: List[Dict]) -> List[str]:
    return [
        f"table2_runtime_{r['query']}_{r['scheme']},{r['total_s'] * 1e6:.0f},"
        f"rows={r['rows']};client_bytes={r['client_bytes']}"
        for r in results
    ]


def validate(results: List[Dict]) -> List[str]:
    fails = []
    by = {(r["query"], r["scheme"]): r for r in results}
    for q in ["A", "B", "C"]:
        scan, bscan = by[(q, "scan")]["total_s"], by[(q, "batched_scan")]["total_s"]
        if bscan > 2.5 * scan + 0.5:
            fails.append(f"Q{q}: batching overhead excessive: scan={scan:.2f} batched={bscan:.2f}")
    idx = {q: by[(q, "index")]["total_s"] for q in "ABC"}
    # Ordering with slack: sub-millisecond runtimes are noise-dominated.
    tol = 1e-3
    if not (idx["C"] <= idx["B"] * 1.5 + tol and idx["B"] <= idx["A"] * 1.5 + tol):
        fails.append(f"index runtime not ordered by selectivity: {idx}")
    # Iterator stack claim: scan-time aggregation must ship fewer bytes
    # than fetching the matching rows (same filter, same range).
    for q in ["A", "B", "C"]:
        row_bytes = by[(q, "batched_scan")]["client_bytes"]
        agg_bytes = by[(q, "combine_scan")]["client_bytes"]
        if by[(q, "combine_scan")]["rows"] and agg_bytes >= row_bytes:
            fails.append(
                f"Q{q}: combine_scan shipped {agg_bytes}B >= row-fetch {row_bytes}B"
            )
    return fails
